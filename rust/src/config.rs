//! Configuration system: layered defaults <- config file <- CLI overrides.
//!
//! The config file is a flat `key = value` format (INI-without-sections) —
//! parsed in-tree because the offline build has no TOML crate. Every knob
//! of the paper's experimental setup lives here so runs are reproducible
//! from a checked-in file (`repro.toml` at the repo root uses only the
//! flat subset of TOML syntax, so it is also valid TOML for humans).

use anyhow::{bail, Context, Result};
use std::path::Path;

/// FCM algorithm parameters (paper Algorithm 1, step 1).
#[derive(Clone, Debug, PartialEq)]
pub struct FcmConfig {
    /// Number of clusters c. Paper: 4 (WM, GM, CSF, background).
    pub clusters: usize,
    /// Fuzziness exponent m. Paper: 2.
    pub m: f32,
    /// Convergence threshold on max |u_new - u_old|. Paper: 0.005.
    pub epsilon: f32,
    /// Safety cap on iterations.
    pub max_iters: usize,
    /// Seed for the random membership initialization (paper step 2).
    pub seed: u64,
}

impl Default for FcmConfig {
    fn default() -> Self {
        FcmConfig {
            clusters: 4,
            m: 2.0,
            epsilon: 0.005,
            max_iters: 300,
            seed: 42,
        }
    }
}

impl FcmConfig {
    pub fn validate(&self) -> Result<()> {
        if self.clusters < 2 {
            bail!("clusters must be >= 2, got {}", self.clusters);
        }
        if !(self.m > 1.0) {
            bail!("fuzziness m must be > 1, got {}", self.m);
        }
        if !(self.epsilon > 0.0) {
            bail!("epsilon must be > 0, got {}", self.epsilon);
        }
        if self.max_iters == 0 {
            bail!("max_iters must be >= 1");
        }
        Ok(())
    }
}

/// Host engine parameters (the `fcm::engine` backend selection).
#[derive(Clone, Debug, PartialEq)]
pub struct EngineConfig {
    /// Which host implementation serves CPU-engine runs:
    /// `sequential` | `parallel` | `histogram`.
    pub backend: crate::fcm::Backend,
    /// Engine worker threads; 0 = all available cores. Results are
    /// identical for every value (deterministic chunked reductions).
    pub threads: usize,
    /// Pixels per reduction chunk. Part of the determinism contract:
    /// changing it changes the fp rounding of the sigma sums (within
    /// tolerance), so it is a config knob, not an auto-tuned value.
    pub chunk: usize,
    /// Slices per resident tile on the out-of-core volume path
    /// (`segment-volume --stream`; `--tile-slices` overrides per run).
    /// Memory budget only — results are identical for every value.
    pub tile_slices: usize,
    /// Double-buffered tile prefetch on the out-of-core volume path: a
    /// dedicated I/O thread reads tile k+1 while the engine computes on
    /// tile k (`image::volume::stream::TilePrefetcher`). Reorders I/O
    /// only — results are identical either way.
    pub prefetch: bool,
    /// Explicit-SIMD fused kernel (`fcm::engine::fused`). `None` leaves
    /// the process-wide default alone (env `REPRO_SIMD`, on by default);
    /// `Some(v)` pins it. Results are bit-identical either way — the
    /// lane-major reduction order is fixed independently of the kernel
    /// (see DESIGN.md), so this is a performance knob only.
    pub simd: Option<bool>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            backend: crate::fcm::Backend::Parallel,
            threads: 0,
            chunk: 4096,
            tile_slices: 8,
            prefetch: true,
            simd: None,
        }
    }
}

impl EngineConfig {
    pub fn validate(&self) -> Result<()> {
        if self.chunk == 0 {
            bail!("engine_chunk must be >= 1");
        }
        if self.tile_slices == 0 {
            bail!("tile_slices must be >= 1");
        }
        Ok(())
    }
}

/// Coordinator / service parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ServiceConfig {
    /// Worker threads, each owning compiled PJRT executables.
    pub workers: usize,
    /// Max jobs grouped into one batch per worker dispatch.
    pub max_batch: usize,
    /// Bounded queue depth before submits exert backpressure.
    pub queue_depth: usize,
    /// Execute a formed batch through one `segment_batch` engine
    /// invocation (true, default) instead of a per-job loop (false —
    /// the A/B lever for the coordinator bench). Results are identical
    /// either way.
    pub batch_execute: bool,
    /// Per-job deadline in milliseconds; 0 = no deadline. The clock
    /// starts at submit, so queue wait counts against it. Expired jobs
    /// come back as typed `Interrupted::DeadlineExceeded` errors and
    /// bump the `cancelled` counter.
    pub job_timeout_ms: u64,
    /// Retry attempts beyond the first for transient I/O failures on
    /// file-backed streamed jobs (safe: engines are deterministic, so a
    /// re-run is bit-identical). 0 disables retries.
    pub max_retries: u32,
    /// Backoff base delay (ms) before the first retry; later attempts
    /// double it, with seeded jitter (`fault::backoff_delay`).
    pub retry_backoff_ms: u64,
    /// Global admission budget: max estimated resident tile bytes in
    /// flight across streamed-volume jobs; 0 = unlimited. Over-budget
    /// submissions wait briefly for capacity, then come back as typed
    /// `Rejected` errors.
    pub resident_budget_bytes: usize,
    /// Period (ms) between metrics expositions while `serve` runs: each
    /// tick dumps the Prometheus text form of the current
    /// [`crate::coordinator::Snapshot`] to stderr. 0 (default) disables
    /// the periodic dump (the shutdown dump always runs).
    pub metrics_interval_ms: u64,
    /// TCP listen address for the networked front door (`serve --listen`
    /// uses this when no `--listen` argument is given). Unset = serve
    /// runs the in-process synthetic workload only.
    pub listen_addr: Option<String>,
    /// Max simultaneous client connections the TCP server accepts;
    /// further connects get a typed `TooManyConnections` error reply.
    pub max_connections: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            // §Perf L3: each PJRT CPU client runs its own intra-op thread
            // pool over all cores, so extra workers contend rather than
            // scale (measured: 1 worker 4.0 jobs/s vs 4 workers 1.1).
            workers: 1,
            max_batch: 8,
            queue_depth: 64,
            batch_execute: true,
            job_timeout_ms: 0,
            max_retries: 2,
            retry_backoff_ms: 50,
            resident_budget_bytes: 0,
            metrics_interval_ms: 0,
            listen_addr: None,
            max_connections: 64,
        }
    }
}

impl ServiceConfig {
    pub fn validate(&self) -> Result<()> {
        if self.workers == 0 || self.max_batch == 0 || self.queue_depth == 0 {
            bail!("service config fields must all be >= 1: {self:?}");
        }
        if self.max_retries > 0 && self.retry_backoff_ms == 0 {
            bail!("retry_backoff_ms must be >= 1 when max_retries > 0 (zero backoff spins hot)");
        }
        if self.max_connections == 0 {
            bail!("max_connections must be >= 1");
        }
        if let Some(a) = &self.listen_addr {
            if a.is_empty() {
                bail!("listen_addr must not be empty when set");
            }
        }
        Ok(())
    }
}

/// Result-cache parameters (`coordinator::cache`).
#[derive(Clone, Debug, PartialEq)]
pub struct CacheConfig {
    /// Content-addressed result cache. Sound because every engine is
    /// bit-deterministic: result bytes are a pure function of (input
    /// bytes, mask bytes, engine, params, output kind). `--no-cache`
    /// flips this off per run.
    pub enabled: bool,
    /// In-memory LRU budget over cached label bytes. Must be >= 1 when
    /// the cache is enabled — a zero budget silently caches nothing,
    /// which should be spelled `cache = false` instead.
    pub capacity_bytes: usize,
    /// Optional directory for the file-backed store (`*.rcache` files,
    /// written `.tmp`-then-rename, digest-verified on load). Unset =
    /// memory-only.
    pub dir: Option<String>,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig {
            enabled: true,
            capacity_bytes: crate::coordinator::cache::DEFAULT_CACHE_CAPACITY,
            dir: None,
        }
    }
}

impl CacheConfig {
    pub fn validate(&self) -> Result<()> {
        if self.enabled && self.capacity_bytes == 0 {
            bail!("cache_capacity_bytes must be >= 1 when the cache is enabled (use cache = false to disable)");
        }
        if let Some(d) = &self.dir {
            if d.is_empty() {
                bail!("cache_dir must not be empty when set");
            }
        }
        Ok(())
    }
}

/// Every key `Config::set` accepts — the CLI forwards matching `--key
/// value` arguments through this list, so adding a knob here is all
/// the wiring a new config field needs.
pub const KEYS: &[&str] = &[
    "clusters",
    "m",
    "epsilon",
    "max_iters",
    "seed",
    "backend",
    "engine_threads",
    "engine_chunk",
    "tile_slices",
    "prefetch",
    "simd",
    "workers",
    "max_batch",
    "queue_depth",
    "batch_execute",
    "job_timeout_ms",
    "max_retries",
    "retry_backoff_ms",
    "resident_budget_bytes",
    "metrics_interval_ms",
    "listen_addr",
    "max_connections",
    "cache",
    "cache_capacity_bytes",
    "cache_dir",
    "artifacts_dir",
];

/// Top-level config.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Config {
    pub fcm: FcmConfig,
    pub engine: EngineConfig,
    pub service: ServiceConfig,
    pub cache: CacheConfig,
    /// Directory holding AOT artifacts + manifest.tsv.
    pub artifacts_dir: String,
}

impl Config {
    pub fn new() -> Config {
        Config {
            fcm: FcmConfig::default(),
            engine: EngineConfig::default(),
            service: ServiceConfig::default(),
            cache: CacheConfig::default(),
            artifacts_dir: "artifacts".to_string(),
        }
    }

    /// Parse the flat `key = value` file format. Unknown keys are errors —
    /// a typo'd knob must not silently fall back to a default.
    pub fn from_str(text: &str) -> Result<Config> {
        let mut cfg = Config::new();
        let kv = parse_flat(text)?;
        for (k, v) in &kv {
            cfg.set(k, v)?;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Config::from_str(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Apply one `key = value` override (also used for `--set k=v` CLI args).
    /// Keep the match arms in sync with [`KEYS`] — a key missing from the
    /// list is never forwarded from direct `--key value` CLI arguments.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let v = value;
        match key {
            "clusters" => self.fcm.clusters = parse(key, v)?,
            "m" => self.fcm.m = parse(key, v)?,
            "epsilon" => self.fcm.epsilon = parse(key, v)?,
            "max_iters" => self.fcm.max_iters = parse(key, v)?,
            "seed" => self.fcm.seed = parse(key, v)?,
            "backend" => self.engine.backend = parse(key, v)?,
            "engine_threads" => self.engine.threads = parse(key, v)?,
            "engine_chunk" => self.engine.chunk = parse(key, v)?,
            "tile_slices" => self.engine.tile_slices = parse(key, v)?,
            "prefetch" => self.engine.prefetch = parse(key, v)?,
            "simd" => self.engine.simd = Some(parse(key, v)?),
            "workers" => self.service.workers = parse(key, v)?,
            "max_batch" => self.service.max_batch = parse(key, v)?,
            "queue_depth" => self.service.queue_depth = parse(key, v)?,
            "batch_execute" => self.service.batch_execute = parse(key, v)?,
            "job_timeout_ms" => self.service.job_timeout_ms = parse(key, v)?,
            "max_retries" => self.service.max_retries = parse(key, v)?,
            "retry_backoff_ms" => self.service.retry_backoff_ms = parse(key, v)?,
            "resident_budget_bytes" => self.service.resident_budget_bytes = parse(key, v)?,
            "metrics_interval_ms" => self.service.metrics_interval_ms = parse(key, v)?,
            "listen_addr" => self.service.listen_addr = Some(v.trim_matches('"').to_string()),
            "max_connections" => self.service.max_connections = parse(key, v)?,
            "cache" => self.cache.enabled = parse(key, v)?,
            "cache_capacity_bytes" => self.cache.capacity_bytes = parse(key, v)?,
            "cache_dir" => self.cache.dir = Some(v.trim_matches('"').to_string()),
            "artifacts_dir" => self.artifacts_dir = v.trim_matches('"').to_string(),
            _ => bail!("unknown config key {key:?}"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        self.fcm.validate()?;
        self.engine.validate()?;
        self.service.validate()?;
        self.cache.validate()
    }
}

fn parse<T: std::str::FromStr>(key: &str, v: &str) -> Result<T> {
    v.parse()
        .map_err(|_| anyhow::anyhow!("config key {key:?}: cannot parse {v:?}"))
}

/// Strip a trailing `# comment` from one config line. A `#` only starts
/// a comment at the beginning of the line or after whitespace — a `#`
/// embedded in a value (`cache_dir = /data/run#3`) is part of the value.
fn strip_comment(raw: &str) -> &str {
    let bytes = raw.as_bytes();
    for (i, &b) in bytes.iter().enumerate() {
        if b == b'#' && (i == 0 || bytes[i - 1].is_ascii_whitespace()) {
            return &raw[..i];
        }
    }
    raw
}

/// `key = value` lines; `#` comments; blank lines ignored.
fn parse_flat(text: &str) -> Result<Vec<(String, String)>> {
    let mut out = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            bail!("config line {}: expected `key = value`, got {raw:?}", i + 1);
        };
        out.push((k.trim().to_string(), v.trim().to_string()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let c = Config::new();
        assert_eq!(c.fcm.clusters, 4);
        assert_eq!(c.fcm.m, 2.0);
        assert_eq!(c.fcm.epsilon, 0.005);
    }

    #[test]
    fn parses_flat_file() {
        let c = Config::from_str("clusters = 3\nepsilon = 0.01\nworkers = 4\n").unwrap();
        assert_eq!(c.fcm.clusters, 3);
        assert_eq!(c.fcm.epsilon, 0.01);
        assert_eq!(c.service.workers, 4);
    }

    #[test]
    fn comments_and_blanks_ok() {
        let c = Config::from_str("# top\n\nseed = 7 # trailing\n").unwrap();
        assert_eq!(c.fcm.seed, 7);
    }

    #[test]
    fn hash_inside_value_is_not_a_comment() {
        // Regression: the old parser split every line at the first `#`,
        // silently truncating `#`-bearing values into a different config.
        let c = Config::from_str("cache_dir = /data/run#3\n").unwrap();
        assert_eq!(c.cache.dir.as_deref(), Some("/data/run#3"));
        // Whitespace before `#` still starts a comment on the same line.
        let c = Config::from_str("cache_dir = /data/run#3 # trailing note\n").unwrap();
        assert_eq!(c.cache.dir.as_deref(), Some("/data/run#3"));
        // Indented full-line comments stay comments.
        let c = Config::from_str("  # indented comment\nseed = 9\n").unwrap();
        assert_eq!(c.fcm.seed, 9);
        // A key=value line where the whole value is a `#`-word.
        let c = Config::from_str("artifacts_dir = a#b#c\n").unwrap();
        assert_eq!(c.artifacts_dir, "a#b#c");
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(Config::from_str("clustersz = 3\n").is_err());
    }

    #[test]
    fn bad_value_rejected() {
        assert!(Config::from_str("clusters = many\n").is_err());
    }

    #[test]
    fn invalid_semantics_rejected() {
        assert!(Config::from_str("clusters = 1\n").is_err());
        assert!(Config::from_str("m = 1.0\n").is_err());
        assert!(Config::from_str("epsilon = 0\n").is_err());
        assert!(Config::from_str("workers = 0\n").is_err());
    }

    #[test]
    fn set_override() {
        let mut c = Config::new();
        c.set("max_iters", "50").unwrap();
        assert_eq!(c.fcm.max_iters, 50);
        assert!(c.set("nope", "1").is_err());
    }

    #[test]
    fn engine_keys_parse_and_validate() {
        let c = Config::from_str(
            "backend = histogram\nengine_threads = 4\nengine_chunk = 1024\ntile_slices = 3\n",
        )
        .unwrap();
        assert_eq!(c.engine.backend, crate::fcm::Backend::Histogram);
        assert_eq!(c.engine.threads, 4);
        assert_eq!(c.engine.chunk, 1024);
        assert_eq!(c.engine.tile_slices, 3);
        assert!(Config::from_str("backend = cuda\n").is_err());
        assert!(Config::from_str("engine_chunk = 0\n").is_err());
        assert!(Config::from_str("tile_slices = 0\n").is_err());
        // Prefetch defaults on; parses as a boolean.
        assert!(Config::new().engine.prefetch);
        assert!(!Config::from_str("prefetch = false\n").unwrap().engine.prefetch);
        assert!(Config::from_str("prefetch = maybe\n").is_err());
        // SIMD: unset by default (env decides), tri-state when given.
        assert_eq!(Config::new().engine.simd, None);
        assert_eq!(Config::from_str("simd = false\n").unwrap().engine.simd, Some(false));
        assert_eq!(Config::from_str("simd = true\n").unwrap().engine.simd, Some(true));
        assert!(Config::from_str("simd = wide\n").is_err());
        // Default: parallel, auto threads.
        let d = Config::new();
        assert_eq!(d.engine.backend, crate::fcm::Backend::Parallel);
        assert_eq!(d.engine.threads, 0);
    }

    #[test]
    fn batch_execute_parses_and_defaults_on() {
        assert!(Config::new().service.batch_execute);
        let c = Config::from_str("batch_execute = false\n").unwrap();
        assert!(!c.service.batch_execute);
        assert!(Config::from_str("batch_execute = maybe\n").is_err());
    }

    #[test]
    fn fault_tolerance_keys_parse_and_validate() {
        let c = Config::from_str(
            "job_timeout_ms = 2500\nmax_retries = 3\nretry_backoff_ms = 10\n\
             resident_budget_bytes = 1048576\n",
        )
        .unwrap();
        assert_eq!(c.service.job_timeout_ms, 2500);
        assert_eq!(c.service.max_retries, 3);
        assert_eq!(c.service.retry_backoff_ms, 10);
        assert_eq!(c.service.resident_budget_bytes, 1 << 20);
        // Defaults: no deadline, unlimited budget, a couple of retries.
        let d = Config::new();
        assert_eq!(d.service.job_timeout_ms, 0);
        assert_eq!(d.service.max_retries, 2);
        assert_eq!(d.service.resident_budget_bytes, 0);
        // Metrics exposition: off by default, plain u64 period.
        assert_eq!(d.service.metrics_interval_ms, 0);
        let e = Config::from_str("metrics_interval_ms = 250\n").unwrap();
        assert_eq!(e.service.metrics_interval_ms, 250);
        assert!(Config::from_str("metrics_interval_ms = fast\n").is_err());
        // Nonsense values: negative timeouts/budgets fail the unsigned
        // parse; a zero backoff with retries enabled fails validation.
        assert!(Config::from_str("job_timeout_ms = -5\n").is_err());
        assert!(Config::from_str("resident_budget_bytes = -1\n").is_err());
        assert!(Config::from_str("max_retries = -1\n").is_err());
        assert!(Config::from_str("max_retries = 1\nretry_backoff_ms = 0\n").is_err());
    }

    #[test]
    fn keys_list_entries_all_settable() {
        // One direction of the KEYS <-> Config::set sync contract; the
        // converse (every match arm listed in KEYS) is a doc'd invariant
        // on `set` that a string match can't enumerate.
        let mut c = Config::new();
        for key in KEYS {
            let probe = match *key {
                "backend" => "parallel",
                "artifacts_dir" | "cache_dir" => "x",
                "listen_addr" => "127.0.0.1:7070",
                "m" | "epsilon" => "2.0",
                "batch_execute" | "prefetch" | "simd" | "cache" => "true",
                _ => "3",
            };
            c.set(key, probe).unwrap_or_else(|e| panic!("key {key}: {e}"));
        }
    }

    #[test]
    fn cache_keys_parse_and_validate() {
        // Defaults: on, 256 MiB budget, memory-only.
        let d = Config::new();
        assert!(d.cache.enabled);
        assert_eq!(d.cache.capacity_bytes, 256 << 20);
        assert_eq!(d.cache.dir, None);
        let c = Config::from_str(
            "cache = true\ncache_capacity_bytes = 4096\ncache_dir = \"/tmp/rc\"\n",
        )
        .unwrap();
        assert_eq!(c.cache.capacity_bytes, 4096);
        assert_eq!(c.cache.dir.as_deref(), Some("/tmp/rc"));
        // Disabled cache needs no budget; an enabled zero budget is a
        // config error, not a silent no-op.
        assert!(Config::from_str("cache = false\ncache_capacity_bytes = 0\n").is_ok());
        assert!(Config::from_str("cache_capacity_bytes = 0\n").is_err());
        assert!(Config::from_str("cache = maybe\n").is_err());
        assert!(Config::from_str("cache_capacity_bytes = lots\n").is_err());
        assert!(Config::from_str("cache_dir = \"\"\n").is_err());
    }

    #[test]
    fn net_keys_parse_and_validate() {
        // Defaults: no listen address (in-process serve), 64 connections.
        let d = Config::new();
        assert_eq!(d.service.listen_addr, None);
        assert_eq!(d.service.max_connections, 64);
        let c = Config::from_str("listen_addr = 127.0.0.1:7070\nmax_connections = 8\n").unwrap();
        assert_eq!(c.service.listen_addr.as_deref(), Some("127.0.0.1:7070"));
        assert_eq!(c.service.max_connections, 8);
        // Quoted form also accepted, like the other string keys.
        let q = Config::from_str("listen_addr = \"0.0.0.0:9000\"\n").unwrap();
        assert_eq!(q.service.listen_addr.as_deref(), Some("0.0.0.0:9000"));
        assert!(Config::from_str("max_connections = 0\n").is_err());
        assert!(Config::from_str("max_connections = lots\n").is_err());
        assert!(Config::from_str("listen_addr = \"\"\n").is_err());
    }

    #[test]
    fn quoted_string_value() {
        let mut c = Config::new();
        c.set("artifacts_dir", "\"/tmp/a\"").unwrap();
        assert_eq!(c.artifacts_dir, "/tmp/a");
    }
}
