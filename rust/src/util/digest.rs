//! Streaming 64-bit-lane content digest for the result cache.
//!
//! Hand-rolled (the build is fully offline — same vendoring discipline
//! as `obs`): four 64-bit lanes consume 32-byte blocks, a tail loop
//! folds the remainder, and the merge mixes in the total length so a
//! prefix never collides with its extension. The algorithm is fixed
//! forever — digests are persisted in the file-backed cache store and
//! in the path→digest memo, so changing a constant silently invalidates
//! every on-disk entry (they re-verify and read as misses, never as
//! stale hits).
//!
//! Properties the cache relies on (tested below):
//!
//! * **streaming-invariant** — `update` call boundaries never affect
//!   the value: hashing a volume tile-by-tile during the engine's first
//!   sweep equals hashing the contiguous buffer in one call;
//! * **length-aware** — `b"ab"` then `finalize` differs from `b"abc"`;
//! * **platform-independent** — little-endian lane loads are explicit,
//!   so the value is the same on every architecture.

const P1: u64 = 0x9E37_79B1_85EB_CA87;
const P2: u64 = 0xC2B2_AE3D_27D4_EB4F;
const P3: u64 = 0x1656_67B1_9E37_79F9;
const P4: u64 = 0x85EB_CA77_C2B2_AE63;
const P5: u64 = 0x27D4_EB2F_1656_67C5;

#[inline]
fn round(acc: u64, input: u64) -> u64 {
    acc.wrapping_add(input.wrapping_mul(P2)).rotate_left(31).wrapping_mul(P1)
}

#[inline]
fn merge_lane(h: u64, lane: u64) -> u64 {
    (h ^ round(0, lane)).wrapping_mul(P1).wrapping_add(P4)
}

/// Incremental 4×64-bit-lane digest. `update` any number of times,
/// `finalize` once.
#[derive(Clone, Debug)]
pub struct Digest64 {
    lanes: [u64; 4],
    /// Tail buffer: bytes not yet forming a full 32-byte block.
    buf: [u8; 32],
    buf_len: usize,
    total: u64,
}

impl Default for Digest64 {
    fn default() -> Self {
        Self::new()
    }
}

impl Digest64 {
    pub fn new() -> Digest64 {
        Digest64 {
            lanes: [
                P1.wrapping_add(P2),
                P2,
                0,
                0u64.wrapping_sub(P1),
            ],
            buf: [0; 32],
            buf_len: 0,
            total: 0,
        }
    }

    /// Fold `bytes` into the state. Call boundaries do not affect the
    /// final value.
    pub fn update(&mut self, bytes: &[u8]) {
        self.total = self.total.wrapping_add(bytes.len() as u64);
        let mut rest = bytes;
        // Top up a partial tail buffer first.
        if self.buf_len > 0 {
            let take = rest.len().min(32 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&rest[..take]);
            self.buf_len += take;
            rest = &rest[take..];
            if self.buf_len < 32 {
                return;
            }
            let block = self.buf;
            self.consume_block(&block);
            self.buf_len = 0;
        }
        // Whole blocks straight from the input.
        let mut chunks = rest.chunks_exact(32);
        for block in &mut chunks {
            let mut b = [0u8; 32];
            b.copy_from_slice(block);
            self.consume_block(&b);
        }
        let tail = chunks.remainder();
        self.buf[..tail.len()].copy_from_slice(tail);
        self.buf_len = tail.len();
    }

    #[inline]
    fn consume_block(&mut self, block: &[u8; 32]) {
        for (i, lane) in self.lanes.iter_mut().enumerate() {
            let mut w = [0u8; 8];
            w.copy_from_slice(&block[i * 8..i * 8 + 8]);
            *lane = round(*lane, u64::from_le_bytes(w));
        }
    }

    /// Collapse the lanes, the tail, and the total length into the
    /// final value. The state is consumed by value so a digest cannot
    /// be finalized twice with interleaved updates.
    pub fn finalize(self) -> u64 {
        let mut h = if self.total >= 32 {
            let mut h = self.lanes[0]
                .rotate_left(1)
                .wrapping_add(self.lanes[1].rotate_left(7))
                .wrapping_add(self.lanes[2].rotate_left(12))
                .wrapping_add(self.lanes[3].rotate_left(18));
            for lane in self.lanes {
                h = merge_lane(h, lane);
            }
            h
        } else {
            // Short input: no block was ever consumed.
            P5
        };
        h = h.wrapping_add(self.total.wrapping_mul(P3));
        for &b in &self.buf[..self.buf_len] {
            h = (h ^ u64::from(b).wrapping_mul(P5)).rotate_left(11).wrapping_mul(P1);
        }
        // Final avalanche.
        h ^= h >> 33;
        h = h.wrapping_mul(P2);
        h ^= h >> 29;
        h = h.wrapping_mul(P3);
        h ^= h >> 32;
        h
    }
}

/// One-shot convenience over [`Digest64`].
pub fn digest_bytes(bytes: &[u8]) -> u64 {
    let mut d = Digest64::new();
    d.update(bytes);
    d.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunking_never_changes_the_value() {
        let data: Vec<u8> = (0..1013u32).map(|i| (i.wrapping_mul(31) % 251) as u8).collect();
        let whole = digest_bytes(&data);
        for chunk in [1usize, 7, 31, 32, 33, 256, 1000] {
            let mut d = Digest64::new();
            for part in data.chunks(chunk) {
                d.update(part);
            }
            assert_eq!(d.finalize(), whole, "chunk size {chunk}");
        }
        // Degenerate empty updates are no-ops.
        let mut d = Digest64::new();
        d.update(&[]);
        d.update(&data);
        d.update(&[]);
        assert_eq!(d.finalize(), whole);
    }

    #[test]
    fn distinct_inputs_distinct_digests() {
        let a = digest_bytes(b"abc");
        assert_ne!(a, digest_bytes(b"abd"));
        assert_ne!(a, digest_bytes(b"ab"));
        assert_ne!(a, digest_bytes(b"abc\0"), "length is folded in");
        assert_ne!(digest_bytes(b""), digest_bytes(b"\0"));
        // A single flipped bit in a long buffer changes the value.
        let data = vec![0u8; 4096];
        let mut flipped = data.clone();
        flipped[2049] ^= 0x10;
        assert_ne!(digest_bytes(&data), digest_bytes(&flipped));
    }

    #[test]
    fn digest_is_stable_across_runs() {
        // Pinned values: the file store and memo persist digests, so
        // the algorithm must never drift between builds.
        let a = digest_bytes(b"");
        let b = digest_bytes(b"repro");
        let data: Vec<u8> = (0..=255u16).map(|i| i as u8).collect();
        let c = digest_bytes(&data);
        assert_eq!(a, digest_bytes(b""));
        assert_eq!(b, digest_bytes(b"repro"));
        assert_eq!(c, digest_bytes(&data));
        assert!(a != b && b != c && a != c);
    }

    #[test]
    fn zero_runs_of_different_lengths_differ() {
        let mut seen = std::collections::HashSet::new();
        for n in 0..200 {
            assert!(seen.insert(digest_bytes(&vec![0u8; n])), "collision at length {n}");
        }
    }
}
