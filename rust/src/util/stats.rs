//! Summary statistics for the in-tree benchmark harness and reports.

/// Robust summary of a sample of measurements (seconds, ratios, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: s[0],
            median: percentile_sorted(&s, 50.0),
            p95: percentile_sorted(&s, 95.0),
            max: s[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (used for aggregate speedup factors).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.median, 3.0);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.5]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.5);
        assert_eq!(s.p95, 7.5);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert_eq!(percentile_sorted(&s, 50.0), 5.0);
        assert_eq!(percentile_sorted(&s, 0.0), 0.0);
        assert_eq!(percentile_sorted(&s, 100.0), 10.0);
    }

    #[test]
    fn geomean_of_speedups() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 1.0, 1.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn summary_empty_panics() {
        let _ = Summary::of(&[]);
    }
}
