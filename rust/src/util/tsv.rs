//! Minimal TSV reader for the artifact manifest (manifest.tsv).
//!
//! The offline build has no serde_json; the AOT step therefore also emits a
//! flat tab-separated manifest with a header row, which this module parses.
//! Deliberately strict: a malformed manifest is a build error, not data.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;

/// A parsed TSV table: header names plus rows of equal arity.
#[derive(Clone, Debug)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
    col: HashMap<String, usize>,
}

impl Table {
    pub fn parse(text: &str) -> Result<Table> {
        let mut lines = text
            .lines()
            .map(str::trim_end)
            .filter(|l| !l.is_empty() && !l.starts_with('#'));
        let header: Vec<String> = lines
            .next()
            .context("empty TSV: missing header")?
            .split('\t')
            .map(str::to_string)
            .collect();
        let col: HashMap<String, usize> = header
            .iter()
            .enumerate()
            .map(|(i, h)| (h.clone(), i))
            .collect();
        if col.len() != header.len() {
            bail!("duplicate column names in TSV header: {header:?}");
        }
        let mut rows = Vec::new();
        for (lineno, line) in lines.enumerate() {
            let row: Vec<String> = line.split('\t').map(str::to_string).collect();
            if row.len() != header.len() {
                bail!(
                    "TSV row {} has {} fields, header has {}",
                    lineno + 2,
                    row.len(),
                    header.len()
                );
            }
            rows.push(row);
        }
        Ok(Table { header, rows, col })
    }

    /// Field accessor by column name.
    pub fn get<'a>(&self, row: &'a [String], name: &str) -> Result<&'a str> {
        let idx = *self
            .col
            .get(name)
            .with_context(|| format!("TSV missing column {name:?}"))?;
        Ok(&row[idx])
    }

    pub fn get_usize(&self, row: &[String], name: &str) -> Result<usize> {
        let s = self.get(row, name)?;
        s.parse()
            .with_context(|| format!("column {name:?}: bad usize {s:?}"))
    }

    pub fn get_f64(&self, row: &[String], name: &str) -> Result<f64> {
        let s = self.get(row, name)?;
        s.parse()
            .with_context(|| format!("column {name:?}: bad f64 {s:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "kind\tpixels\tpath\nfcm_iteration\t256\ta.hlo.txt\nblock_sum\t16384\tb.hlo.txt\n";

    #[test]
    fn parses_rows_and_columns() {
        let t = Table::parse(SAMPLE).unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.get(&t.rows[0], "kind").unwrap(), "fcm_iteration");
        assert_eq!(t.get_usize(&t.rows[1], "pixels").unwrap(), 16384);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let text = format!("# comment\n\n{SAMPLE}");
        assert_eq!(Table::parse(&text).unwrap().rows.len(), 2);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(Table::parse("a\tb\n1\n").is_err());
    }

    #[test]
    fn rejects_missing_column() {
        let t = Table::parse(SAMPLE).unwrap();
        assert!(t.get(&t.rows[0], "nope").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(Table::parse("").is_err());
    }

    #[test]
    fn rejects_duplicate_header() {
        assert!(Table::parse("a\ta\n1\t2\n").is_err());
    }

    #[test]
    fn rejects_bad_number() {
        let t = Table::parse("n\nxyz\n").unwrap();
        assert!(t.get_usize(&t.rows[0], "n").is_err());
    }
}
