//! Small self-contained utilities (offline build: no external crates).

pub mod rng;
pub mod stats;
pub mod tsv;

pub use rng::Rng64;
pub use stats::Summary;
