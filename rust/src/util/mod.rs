//! Small self-contained utilities (offline build: no external crates).

pub mod digest;
pub mod rng;
pub mod stats;
pub mod tsv;

pub use digest::{digest_bytes, Digest64};
pub use rng::Rng64;
pub use stats::Summary;
