//! Deterministic PRNG for membership initialization, phantom noise and the
//! in-tree property-test harness.
//!
//! xoshiro256++ seeded through splitmix64 — the standard recommendation for
//! reproducible simulation streams. Implemented in-tree because the build is
//! fully offline (DESIGN.md section 4 / Cargo.toml note).

/// xoshiro256++ PRNG. Deterministic for a given seed on every platform.
#[derive(Clone, Debug)]
pub struct Rng64 {
    s: [u64; 4],
}

impl Rng64 {
    /// Seed via splitmix64 so that small/sequential seeds give well-mixed
    /// initial states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng64 {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high-quality mantissa bits.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n). Unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (1.0 - self.next_f64()) as f32; // (0, 1]: avoid ln(0)
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Gaussian with the given mean/std.
    pub fn gauss(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Rician-distributed sample around `a` with noise sigma — the MRI
    /// magnitude-image noise model used for the phantom substrate.
    pub fn rician(&mut self, a: f32, sigma: f32) -> f32 {
        let re = a + sigma * self.normal();
        let im = sigma * self.normal();
        (re * re + im * im).sqrt()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = (0..8).map(|_| 0).collect::<Vec<_>>();
        let mut r1 = Rng64::new(42);
        let mut r2 = Rng64::new(42);
        let v1: Vec<u64> = a.iter().map(|_| r1.next_u64()).collect();
        let v2: Vec<u64> = a.iter().map(|_| r2.next_u64()).collect();
        assert_eq!(v1, v2);
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng64::new(1).next_u64(), Rng64::new(2).next_u64());
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng64::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng64::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(0.0, 10.0) as f64).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng64::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng64::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn rician_is_nonnegative_and_biased_up() {
        let mut r = Rng64::new(9);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.rician(10.0, 2.0) as f64).sum::<f64>() / n as f64;
        // Rician magnitude mean exceeds the underlying amplitude.
        assert!(mean >= 10.0 && mean < 10.5, "mean={mean}");
        let mut r2 = Rng64::new(10);
        assert!((0..10_000).all(|_| r2.rician(0.0, 1.0) >= 0.0));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng64::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
