//! Experiment harnesses — one function per paper table/figure (DESIGN.md
//! section 5). Shared by the CLI (`repro bench-*`) and the bench binaries
//! so `cargo bench` and the launcher produce identical reports.

use crate::config::Config;
use crate::eval::dice_per_class;
use crate::fcm::{canonical_relabel, FcmParams};
use crate::gpu_sim::{CostModel, PAPER_TABLE3, TESLA_C2050};
use crate::harness::{self, Opts};
use crate::image::{pgm, FeatureVector};
use crate::phantom::{self, dataset::TABLE3_SIZES, PhantomConfig};
use crate::report::{fmt_secs, fmt_x, Table};
use crate::runtime::{FcmExecutor, Registry};
use anyhow::{Context, Result};
use std::path::Path;

/// Registry for the measured-device columns, only when the device path
/// is genuinely usable (artifacts + real xla crate; the vendored stub
/// parses manifests but cannot compile, which would panic mid-bench).
fn device_registry(cfg: &Config) -> Option<Registry> {
    let dir = Path::new(&cfg.artifacts_dir);
    if crate::runtime::device_available(dir) {
        Registry::open(dir).ok()
    } else {
        None
    }
}

/// E8 — Table 3: execution time of sequential vs parallel FCM.
///
/// Time columns per size:
///   * `sim seq` / `sim par` — the calibrated C2050/i5 cost model
///     (the testbed substitute; reproduces the paper's numbers),
///   * `our seq` — the paper-faithful sequential baseline, measured,
///   * `our par` / `our hist` — the host engine (fcm::engine) with the
///     parallel and histogram backends, measured,
///   * `our dev` — the PJRT device path (`-` when artifacts are absent).
/// Paper columns are printed alongside for direct comparison.
pub fn table3(cfg: &Config, sizes: &[usize], runs: usize) -> Result<Table> {
    let model = CostModel::calibrated_c2050();
    // Device path is optional: without a usable device (artifacts + real
    // xla crate) the host columns still measure — the degraded mode
    // every offline checkout starts in.
    let registry = device_registry(cfg);
    let params = FcmParams::from(&cfg.fcm);
    let engine_opts = crate::fcm::EngineOpts::from(&cfg.engine);
    let opts = Opts {
        warmup: 1,
        min_runs: runs.min(3),
        max_runs: runs,
        max_seconds: 20.0,
    };

    let mut t = Table::new([
        "size", "paper seq(s)", "paper par(s)", "sim seq(s)", "sim par(s)", "our seq(s)",
        "our par(s)", "our hist(s)", "our dev(s)", "par x", "hist x", "dev x",
    ]);
    for &bytes in sizes {
        let kb = bytes / 1024;
        let paper = PAPER_TABLE3.iter().find(|(pkb, _, _)| *pkb == kb);
        let data = phantom::sized_dataset(bytes, cfg.fcm.seed);
        let fv = FeatureVector::from_image(&data.image);

        let seq = harness::bench(&format!("seq-{kb}KB"), &opts, || {
            let _ = crate::fcm::sequential::run(&fv.x, &fv.w, &params);
        });
        let par = harness::bench(&format!("par-{kb}KB"), &opts, || {
            let o = crate::fcm::EngineOpts {
                backend: crate::fcm::Backend::Parallel,
                ..engine_opts
            };
            let _ = crate::fcm::engine::run(&fv.x, &fv.w, &params, &o);
        });
        let hist = harness::bench(&format!("hist-{kb}KB"), &opts, || {
            let o = crate::fcm::EngineOpts {
                backend: crate::fcm::Backend::Histogram,
                ..engine_opts
            };
            let _ = crate::fcm::engine::run(&fv.x, &fv.w, &params, &o);
        });
        let dev = registry.as_ref().map(|reg| {
            let executor = FcmExecutor::new(reg);
            harness::bench(&format!("dev-{kb}KB"), &opts, || {
                let _ = executor.segment(&fv, &params).expect("device run");
            })
        });

        t.row([
            format!("{kb}KB"),
            paper.map_or("-".into(), |p| fmt_secs(p.1)),
            paper.map_or("-".into(), |p| fmt_secs(p.2)),
            fmt_secs(model.seq_seconds(bytes)),
            fmt_secs(model.par_seconds(bytes)),
            fmt_secs(seq.mean()),
            fmt_secs(par.mean()),
            fmt_secs(hist.mean()),
            dev.as_ref().map_or("-".into(), |d| fmt_secs(d.mean())),
            fmt_x(seq.mean() / par.mean()),
            fmt_x(seq.mean() / hist.mean()),
            dev.as_ref().map_or("-".into(), |d| fmt_x(seq.mean() / d.mean())),
        ]);
    }
    Ok(t)
}

/// E9 — Fig. 8: the speedup curve with the 448-processor line.
/// Returns (table, ascii chart).
pub fn fig8(sizes: &[usize]) -> (Table, String) {
    let model = CostModel::calibrated_c2050();
    let mut t = Table::new(["size", "sim speedup", "superlinear(>448)?", "paper speedup"]);
    let mut series = Vec::new();
    for &bytes in sizes {
        let kb = bytes / 1024;
        let s = model.speedup(bytes);
        series.push((kb, s));
        let paper = PAPER_TABLE3
            .iter()
            .find(|(pkb, _, _)| *pkb == kb)
            .map(|(_, sq, pr)| sq / pr);
        t.row([
            format!("{kb}KB"),
            format!("{s:.0}"),
            if s > TESLA_C2050.processors as f64 {
                "YES".to_string()
            } else {
                "no".to_string()
            },
            paper.map_or("-".into(), |p| format!("{p:.0}")),
        ]);
    }
    (t, ascii_chart(&series, TESLA_C2050.processors as f64))
}

/// Minimal ASCII rendering of the Fig. 8 curve (log-ish x by index).
fn ascii_chart(series: &[(usize, f64)], hline: f64) -> String {
    let max = series
        .iter()
        .map(|&(_, s)| s)
        .fold(hline, f64::max)
        .max(1.0);
    let height = 16usize;
    let mut out = String::new();
    out.push_str(&format!(
        "speedup vs size ({} pts); '-' = {} PEs (Tesla C2050)\n",
        series.len(),
        hline
    ));
    for level in (0..=height).rev() {
        let thresh = max * level as f64 / height as f64;
        let hline_row = (hline / max * height as f64).round() as usize == level;
        let mut line = format!("{:>5.0} |", thresh);
        for &(_, s) in series {
            let filled = (s / max * height as f64).round() as usize >= level;
            line.push(if filled {
                '*'
            } else if hline_row {
                '-'
            } else {
                ' '
            });
            line.push(' ');
        }
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str("      +");
    out.push_str(&"--".repeat(series.len()));
    out.push('\n');
    out.push_str("       ");
    for &(kb, _) in series {
        if kb >= 1000 {
            out.push_str("1M");
        } else {
            out.push_str(&format!("{}", kb / 10 % 10));
            out.push(' ');
        }
    }
    out.push_str("  (KB/10, see table)\n");
    out
}

/// E7 — Fig. 7: DSC per tissue for slices 91/96/101/111, sequential FCM
/// vs the parallel (device) FCM, both against ground truth.
pub fn fig7(cfg: &Config) -> Result<Table> {
    let registry = Registry::open(Path::new(&cfg.artifacts_dir))?;
    let executor = FcmExecutor::new(&registry);
    let params = FcmParams::from(&cfg.fcm);
    let mut t = Table::new([
        "slice", "region", "DSC seq(%)", "DSC par(%)", "|diff|",
    ]);
    for slice in [91usize, 96, 101, 111] {
        let s = phantom::generate_slice(&PhantomConfig {
            slice,
            seed: cfg.fcm.seed,
            ..PhantomConfig::default()
        });
        let fv = FeatureVector::from_image(&s.image);
        let mut seq = crate::fcm::sequential::run(&fv.x, &fv.w, &params);
        canonical_relabel(&mut seq);
        let (mut dev, _) = executor.segment(&fv, &params)?;
        canonical_relabel(&mut dev);
        let d_seq = dice_per_class(&seq.labels, &s.ground_truth.labels, 4);
        let d_dev = dice_per_class(&dev.labels, &s.ground_truth.labels, 4);
        for (cls, name) in ["Background", "CSF", "GM", "WM"].iter().enumerate() {
            t.row([
                format!("{slice}"),
                name.to_string(),
                format!("{:.2}", d_seq[cls] * 100.0),
                format!("{:.2}", d_dev[cls] * 100.0),
                format!("{:.3}", (d_seq[cls] - d_dev[cls]).abs() * 100.0),
            ]);
        }
    }
    Ok(t)
}

/// E5 — Fig. 5: qualitative side-by-side segmentations written as PGMs.
pub fn fig5(cfg: &Config, outdir: &Path) -> Result<Vec<String>> {
    std::fs::create_dir_all(outdir)?;
    let registry = Registry::open(Path::new(&cfg.artifacts_dir))?;
    let executor = FcmExecutor::new(&registry);
    let params = FcmParams::from(&cfg.fcm);
    let mut written = Vec::new();
    for slice in [101usize, 91, 96] {
        let s = phantom::generate_slice(&PhantomConfig {
            slice,
            seed: cfg.fcm.seed,
            ..PhantomConfig::default()
        });
        let fv = FeatureVector::from_image(&s.image);
        let mut seq = crate::fcm::sequential::run(&fv.x, &fv.w, &params);
        canonical_relabel(&mut seq);
        let (mut dev, _) = executor.segment(&fv, &params)?;
        canonical_relabel(&mut dev);
        let (w, h) = (s.image.width, s.image.height);
        let outputs = [
            (format!("slice{slice}_input.pgm"), s.image.clone()),
            (
                format!("slice{slice}_seq.pgm"),
                crate::image::LabelMap::from_labels(w, h, seq.labels.clone()).to_image(4),
            ),
            (
                format!("slice{slice}_parallel.pgm"),
                crate::image::LabelMap::from_labels(w, h, dev.labels.clone()).to_image(4),
            ),
        ];
        for (name, img) in outputs {
            let p = outdir.join(&name);
            pgm::write(&img, &p)?;
            written.push(p.display().to_string());
        }
        let agree = seq
            .labels
            .iter()
            .zip(&dev.labels)
            .filter(|(a, b)| a == b)
            .count();
        written.push(format!(
            "  slice {slice}: seq/parallel agreement {}/{} px",
            agree,
            seq.labels.len()
        ));
    }
    Ok(written)
}

/// E6 — Fig. 6: ground-truth masks for one slice.
pub fn fig6(cfg: &Config, slice: usize, outdir: &Path) -> Result<Vec<String>> {
    std::fs::create_dir_all(outdir)?;
    let s = phantom::generate_slice(&PhantomConfig {
        slice,
        seed: cfg.fcm.seed,
        ..PhantomConfig::default()
    });
    let (w, h) = (s.image.width, s.image.height);
    let mut written = Vec::new();
    let mut emit = |name: String, img: crate::image::GrayImage| -> Result<()> {
        let p = outdir.join(&name);
        pgm::write(&img, &p)?;
        written.push(p.display().to_string());
        Ok(())
    };
    emit(format!("slice{slice}_phantom.pgm"), s.image.clone())?;
    for (cls, name) in ["background", "csf", "gm", "wm"].iter().enumerate() {
        let mask = s.ground_truth.mask(cls as u8);
        let img = crate::image::GrayImage::from_pixels(
            w,
            h,
            mask.iter().map(|&b| if b { 255 } else { 0 }).collect(),
        );
        emit(format!("slice{slice}_gt_{name}.pgm"), img)?;
    }
    Ok(written)
}

/// E1 — Table 1: our stack's measured speedups in the related-work frame.
pub fn table1(cfg: &Config, runs: usize) -> Result<Table> {
    let params = FcmParams::from(&cfg.fcm);
    let registry = device_registry(cfg);
    let engine_opts = crate::fcm::EngineOpts::from(&cfg.engine);
    // A 310k-pixel workload, matching the largest related-work object area
    // (Rowinska et al.); also ~the paper's 300KB row.
    let data = phantom::sized_dataset(310 * 1024, cfg.fcm.seed);
    let fv = FeatureVector::from_image(&data.image);
    let px: Vec<u8> = data.image.pixels.clone();
    let opts = Opts {
        warmup: 1,
        min_runs: runs.min(3),
        max_runs: runs,
        max_seconds: 30.0,
    };

    let seq = harness::bench("seq", &opts, || {
        let _ = crate::fcm::sequential::run(&fv.x, &fv.w, &params);
    });
    let dev = registry.as_ref().map(|reg| {
        let executor = FcmExecutor::new(reg);
        harness::bench("dev", &opts, || {
            let _ = executor.segment(&fv, &params).expect("device");
        })
    });
    let par = harness::bench("engine-par", &opts, || {
        let o = crate::fcm::EngineOpts {
            backend: crate::fcm::Backend::Parallel,
            ..engine_opts
        };
        let _ = crate::fcm::engine::run(&fv.x, &fv.w, &params, &o);
    });
    let hist = harness::bench("engine-hist", &opts, || {
        let o = crate::fcm::EngineOpts {
            backend: crate::fcm::Backend::Histogram,
            ..engine_opts
        };
        let _ = crate::fcm::engine::run(&fv.x, &fv.w, &params, &o);
    });
    let br = harness::bench("brfcm", &opts, || {
        let _ = crate::fcm::brfcm::run_on_pixels(&px, &params);
    });
    let km = harness::bench("kmeans", &opts, || {
        let _ = crate::fcm::kmeans::run(&fv.x, &fv.w, params.clusters, params.max_iters, 1e-3, params.seed);
    });
    let model = CostModel::calibrated_c2050();

    let mut t = Table::new(["method (this repo, 310k px)", "time(s)", "speedup vs seq FCM"]);
    t.row(["sequential FCM (paper baseline)", &fmt_secs(seq.mean()), "1x"]);
    match &dev {
        Some(d) => {
            t.row([
                "parallel FCM, AOT device path",
                &fmt_secs(d.mean()),
                &fmt_x(seq.mean() / d.mean()),
            ]);
        }
        None => {
            t.row(["parallel FCM, AOT device path", "-", "(no artifacts)"]);
        }
    }
    t.row([
        "host engine, parallel backend",
        &fmt_secs(par.mean()),
        &fmt_x(seq.mean() / par.mean()),
    ]);
    t.row([
        "host engine, histogram backend",
        &fmt_secs(hist.mean()),
        &fmt_x(seq.mean() / hist.mean()),
    ]);
    t.row([
        "brFCM (Eschrich; Mahmoud et al. row)",
        &fmt_secs(br.mean()),
        &fmt_x(seq.mean() / br.mean()),
    ]);
    t.row([
        "K-Means (hard baseline, Sec. 1)",
        &fmt_secs(km.mean()),
        &fmt_x(seq.mean() / km.mean()),
    ]);
    t.row([
        "paper's C2050 model @300KB (sim)",
        &fmt_secs(model.par_seconds(300 * 1024)),
        &fmt_x(model.speedup(300 * 1024)),
    ]);
    Ok(t)
}

/// E10 — ablation of the cost model's components (the Section 5.3
/// open questions).
pub fn ablation(sizes: &[usize]) -> Table {
    let base = CostModel::calibrated_c2050();
    let mut no_bump = base.clone();
    no_bump.enable_bump = false;
    let mut no_transfer = base.clone();
    no_transfer.enable_transfer = false;
    let mut no_launch = base.clone();
    no_launch.enable_launch_overhead = false;
    let mut cache = base.clone();
    cache.cpu_cache_penalty = 0.5; // what a cache-bound CPU baseline adds

    let mut t = Table::new([
        "size",
        "speedup",
        "no contention bump",
        "no PCIe transfer",
        "no launch overhead",
        "cache-bound CPU",
    ]);
    for &bytes in sizes {
        t.row([
            format!("{}KB", bytes / 1024),
            format!("{:.0}", base.speedup(bytes)),
            format!("{:.0}", no_bump.speedup(bytes)),
            format!("{:.0}", no_transfer.speedup(bytes)),
            format!("{:.0}", no_launch.speedup(bytes)),
            format!("{:.0}", cache.speedup(bytes)),
        ]);
    }
    t
}

/// E3 — the Algorithm-2 reduction demo on the device.
pub fn reduction_demo(cfg: &Config) -> Result<String> {
    let registry = Registry::open(Path::new(&cfg.artifacts_dir))?;
    let executor = FcmExecutor::new(&registry);
    let n = 16384usize;
    let a: Vec<f32> = (0..n).map(|i| (i % 7) as f32).collect();
    let partials = executor.block_sum(&a)?;
    let total: f32 = partials.iter().sum();
    let expect: f32 = a.iter().sum();
    let mut out = String::new();
    out.push_str(&format!(
        "Algorithm 2 on device: {} elements -> {} partial sums (block {}),\n",
        n,
        partials.len(),
        n / partials.len()
    ));
    out.push_str(&format!(
        "first partials: {:?}\n",
        &partials[..4.min(partials.len())]
    ));
    out.push_str(&format!(
        "final sum {total} (flat reference {expect}) — paper's 1MB example: 1048576 B -> 4096 B of partials\n"
    ));
    anyhow::ensure!((total - expect).abs() / expect < 1e-4, "reduction mismatch");
    Ok(out)
}

/// Default Table 3 sizes, trimmed in quick mode (CI-friendly).
pub fn table3_sizes(quick: bool) -> Vec<usize> {
    if quick {
        vec![20 * 1024, 100 * 1024, 300 * 1024]
    } else {
        TABLE3_SIZES.to_vec()
    }
}

/// Fig. 8 x-axis: a denser sweep than Table 3 to resolve the crossovers.
pub fn fig8_sizes() -> Vec<usize> {
    let mut v: Vec<usize> = TABLE3_SIZES.to_vec();
    for kb in [250usize, 360, 400, 450, 600, 850] {
        v.push(kb * 1024);
    }
    v.sort();
    v
}

/// Parse a human size list like "20KB,100KB,1MB".
pub fn parse_sizes(s: &str) -> Result<Vec<usize>> {
    s.split(',')
        .map(|tok| {
            let tok = tok.trim().to_ascii_uppercase();
            let (num, mult) = if let Some(n) = tok.strip_suffix("MB") {
                (n, 1024 * 1024)
            } else if let Some(n) = tok.strip_suffix("KB") {
                (n, 1024)
            } else if let Some(n) = tok.strip_suffix('B') {
                (n, 1)
            } else {
                (tok.as_str(), 1)
            };
            num.trim()
                .parse::<usize>()
                .map(|v| v * mult)
                .with_context(|| format!("bad size token {tok:?}"))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes_units() {
        assert_eq!(parse_sizes("20KB,1MB,77B,5").unwrap(), vec![20480, 1048576, 77, 5]);
        assert!(parse_sizes("x").is_err());
    }

    #[test]
    fn fig8_has_dense_sweep() {
        let s = fig8_sizes();
        assert!(s.len() > TABLE3_SIZES.len());
        assert!(s.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn ablation_bump_column_monotone_region() {
        let t = ablation(&[200 * 1024]);
        // (Formatting-level check: table renders with 6 columns.)
        assert!(t.to_text().lines().next().unwrap().contains("no contention bump"));
    }

    #[test]
    fn ascii_chart_renders() {
        let chart = super::ascii_chart(&[(20, 560.0), (200, 385.0), (1000, 666.0)], 448.0);
        assert!(chart.contains('*'));
        assert!(chart.contains('-'));
    }
}

/// Extension experiment: segmentation robustness to scanner noise and
/// intensity non-uniformity (the two corruption knobs of the BrainWeb
/// simulator the paper's dataset came from). DSC vs noise/INU level for
/// the sequential and device paths — quantifies when the 4-mode intensity
/// assumption behind FCM degrades.
pub fn robustness(cfg: &Config) -> Result<Table> {
    let registry = Registry::open(Path::new(&cfg.artifacts_dir))?;
    let executor = FcmExecutor::new(&registry);
    let params = FcmParams::from(&cfg.fcm);
    let mut t = Table::new([
        "noise sigma", "INU", "mean DSC seq", "mean DSC par", "iters seq", "iters par",
    ]);
    for (noise, inu) in [
        (0.0f32, 0.0f32),
        (4.0, 0.0),
        (8.0, 0.0),
        (12.0, 0.0),
        (4.0, 0.2),
        (4.0, 0.4),
        (8.0, 0.4),
    ] {
        let s = phantom::generate_slice(&PhantomConfig {
            slice: 96,
            noise_sigma: noise,
            bias_amplitude: inu,
            seed: cfg.fcm.seed,
            ..PhantomConfig::default()
        });
        let fv = FeatureVector::from_image(&s.image);
        let mut seq = crate::fcm::sequential::run(&fv.x, &fv.w, &params);
        canonical_relabel(&mut seq);
        let (mut dev, _) = executor.segment(&fv, &params)?;
        canonical_relabel(&mut dev);
        let mean = |labels: &[u8]| -> f64 {
            dice_per_class(labels, &s.ground_truth.labels, 4)
                .iter()
                .sum::<f64>()
                / 4.0
        };
        t.row([
            format!("{noise}"),
            format!("{inu}"),
            format!("{:.4}", mean(&seq.labels)),
            format!("{:.4}", mean(&dev.labels)),
            format!("{}", seq.iterations),
            format!("{}", dev.iterations),
        ]);
    }
    Ok(t)
}
