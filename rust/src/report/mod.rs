//! Report formatting: aligned ASCII / markdown tables for the bench
//! harnesses that regenerate the paper's tables and figures.

/// A simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(headers: I) -> Table {
        Table {
            headers: headers.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, cells: I) -> &mut Table {
        let row: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(row);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Render as aligned plain text (right-aligned data columns, left-
    /// aligned first column).
    pub fn to_text(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    line.push_str(&format!("{:<width$}", c, width = w[i]));
                } else {
                    line.push_str(&format!("  {:>width$}", c, width = w[i]));
                }
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &w));
        let total: usize = w.iter().sum::<usize>() + 2 * (w.len() - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
        }
        out
    }

    /// Render as a GitHub-markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers
                .iter()
                .map(|_| "---")
                .collect::<Vec<_>>()
                .join("|")
        ));
        for row in &self.rows {
            out.push_str(&format!("| {} |\n", row.join(" | ")));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_text());
    }
}

/// Format seconds sensibly across the paper's 0.1s..2798s range.
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{s:.0}")
    } else if s >= 1.0 {
        format!("{s:.2}")
    } else if s >= 0.001 {
        format!("{s:.3}")
    } else {
        "<0.001".to_string()
    }
}

/// Format a speedup factor (decimals only where they carry information).
pub fn fmt_x(f: f64) -> String {
    if f < 10.0 {
        format!("{f:.2}x")
    } else {
        format!("{f:.0}x")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn text_alignment() {
        let mut t = Table::new(["size", "seq", "par"]);
        t.row(["20KB", "57", "0.102"]);
        t.row(["1000KB", "2798", "4.2"]);
        let text = t.to_text();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines same width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
        assert!(lines[0].starts_with("size"));
        assert!(lines[3].starts_with("1000KB"));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(["a", "b"]);
        t.row(["1", "2"]);
        let md = t.to_markdown();
        assert_eq!(md, "| a | b |\n|---|---|\n| 1 | 2 |\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        Table::new(["a", "b"]).row(["only-one"]);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert_eq!(fmt_secs(2798.0), "2798");
        assert_eq!(fmt_secs(4.2), "4.20");
        assert_eq!(fmt_secs(0.102), "0.102");
    }
}

pub mod experiments;
