//! # repro — GPU-Based Fuzzy C-Means for Image Segmentation
//!
//! A three-layer reproduction of Almazrooie, Vadiveloo & Abdullah (2016),
//! *"GPU-Based Fuzzy C-Means Clustering Algorithm for Image Segmentation"*:
//!
//! * **L1/L2** (build time, Python): Pallas kernels + a JAX iteration graph,
//!   AOT-lowered to HLO text artifacts (`python/compile/`).
//! * **L3** (this crate): the coordinator — PJRT runtime executing the
//!   artifacts on the request path, plus every substrate the paper's
//!   evaluation needs: phantom data, skull stripping, sequential/brFCM/
//!   K-Means baselines, DSC evaluation, a calibrated GPU/CPU cost model,
//!   and a threaded segmentation service.
//!
//! See DESIGN.md for the system inventory and the per-experiment index.

pub mod config;
pub mod coordinator;
pub mod eval;
pub mod fcm;
pub mod gpu_sim;
pub mod harness;
pub mod image;
pub mod net;
pub mod obs;
pub mod phantom;
pub mod report;
pub mod runtime;
pub mod util;
pub mod cli;
