//! Blocking TCP client for the serving front door.
//!
//! Thin by design: every byte it writes or reads goes through the same
//! [`super::protocol`] codec the server uses, so the two ends cannot
//! drift. One request is in flight per connection at a time (matching
//! the server's one-request-per-handler discipline); a submit that hits
//! a full service queue simply blocks here until the queue drains —
//! remote backpressure, not an error.

use super::protocol::{
    decode_reply, encode_request, read_frame, write_frame, ErrorCode, JobState, Reply, Request,
    SubmitJob, WireResult,
};
use anyhow::{anyhow, bail, Context, Result};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// A typed server-side failure, reconstructed from a wire error reply.
/// Downcast from `anyhow::Error` to branch on the code — the remote
/// analogue of downcasting `Rejected`/`Interrupted` in-process.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RemoteError {
    pub code: ErrorCode,
    pub message: String,
}

impl std::fmt::Display for RemoteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server error ({:?}): {}", self.code, self.message)
    }
}

impl std::error::Error for RemoteError {}

/// Blocking connection to a [`super::server::Server`].
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream =
            TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Ok(Client { stream })
    }

    /// One round trip: write the request frame, read the reply frame.
    /// A server [`Reply::Error`] comes back as a typed [`RemoteError`].
    fn call(&mut self, req: &Request) -> Result<Reply> {
        write_frame(&mut self.stream, &encode_request(req))?;
        let payload = read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        let reply = decode_reply(&payload)?;
        if let Reply::Error { code, message } = reply {
            return Err(anyhow::Error::new(RemoteError { code, message }));
        }
        Ok(reply)
    }

    pub fn ping(&mut self) -> Result<()> {
        match self.call(&Request::Ping)? {
            Reply::Pong => Ok(()),
            r => bail!("unexpected reply to ping: {r:?}"),
        }
    }

    /// Submit a job; returns the server-assigned job id.
    pub fn submit(&mut self, job: SubmitJob) -> Result<u64> {
        match self.call(&Request::Submit(job))? {
            Reply::Submitted { id } => Ok(id),
            r => bail!("unexpected reply to submit: {r:?}"),
        }
    }

    pub fn status(&mut self, id: u64) -> Result<JobState> {
        match self.call(&Request::Status { id })? {
            Reply::Status { state, .. } => Ok(state),
            r => bail!("unexpected reply to status: {r:?}"),
        }
    }

    /// Fetch a completed job's result. A still-pending job comes back
    /// as [`ErrorCode::NotReady`]; a failed job replays its typed
    /// failure code.
    pub fn fetch(&mut self, id: u64) -> Result<WireResult> {
        match self.call(&Request::Fetch { id })? {
            Reply::Result(r) => Ok(*r),
            r => bail!("unexpected reply to fetch: {r:?}"),
        }
    }

    /// The server's Prometheus metrics exposition.
    pub fn metrics(&mut self) -> Result<String> {
        match self.call(&Request::Metrics)? {
            Reply::Metrics { prometheus } => Ok(prometheus),
            r => bail!("unexpected reply to metrics: {r:?}"),
        }
    }

    /// Ask the server to drain and shut down gracefully.
    pub fn shutdown_server(&mut self) -> Result<()> {
        match self.call(&Request::Shutdown)? {
            Reply::ShutdownAck => Ok(()),
            r => bail!("unexpected reply to shutdown: {r:?}"),
        }
    }

    /// Poll until the job reaches a terminal state, then fetch it. A
    /// failed job's typed [`RemoteError`] propagates from the fetch.
    pub fn wait(&mut self, id: u64, poll: Duration, timeout: Duration) -> Result<WireResult> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.status(id)? {
                JobState::Pending => {
                    if Instant::now() >= deadline {
                        bail!("timed out after {timeout:?} waiting for job {id}");
                    }
                    std::thread::sleep(poll);
                }
                JobState::Done | JobState::Failed => return self.fetch(id),
            }
        }
    }
}
