//! Wire protocol for the networked serving front door.
//!
//! One frame = a `u32` little-endian payload length, then the payload:
//! a `u8` message tag followed by the tag's fixed header and body. Both
//! the server and the client encode/decode through THIS module's
//! [`encode_request`]/[`decode_request`]/[`encode_reply`]/[`decode_reply`]
//! — one codec, so the two sides cannot drift.
//!
//! Everything is explicit little-endian integers and length-prefixed
//! byte strings; no serde, no external crates (the offline build rule).
//! Decoding is total: any malformed input comes back as a typed
//! [`WireError`], never a panic — the server's fuzz-shaped rejection
//! sweep (`tests/net.rs`) rides on that.
//!
//! The error taxonomy of the in-process service round-trips as distinct
//! [`ErrorCode`]s: admission rejection, cancellation, deadline expiry,
//! and a queue that refuses work are all distinguishable to a remote
//! client, exactly as they are to an in-process caller.

use crate::coordinator::{Engine, Priority};
use crate::fcm::FcmParams;

/// Hard ceiling on one frame's declared payload length (64 MiB — a
/// 2048³ label volume streams through files, not frames). A declared
/// length beyond this is rejected before any allocation, so a hostile
/// header cannot balloon server memory.
pub const MAX_FRAME: u32 = 64 << 20;

/// Typed decode failure. Every way a frame can be malformed maps here;
/// the server answers with [`ErrorCode::BadRequest`] (or drops the
/// connection) instead of panicking.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// The payload ended before a field's declared extent.
    Truncated { needed: usize, have: usize },
    /// The frame header declared a payload larger than [`MAX_FRAME`].
    Oversized { declared: u32 },
    /// The payload's leading message tag names no known message.
    UnknownTag(u8),
    /// A field held an out-of-domain value (bad enum byte, non-UTF-8
    /// string, shape/byte-count mismatch).
    BadValue(&'static str),
    /// Bytes left over after a complete message was decoded.
    TrailingBytes(usize),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { needed, have } => {
                write!(f, "truncated frame: needed {needed} bytes, have {have}")
            }
            WireError::Oversized { declared } => {
                write!(f, "oversized frame: declared {declared} bytes (max {MAX_FRAME})")
            }
            WireError::UnknownTag(t) => write!(f, "unknown message tag {t:#04x}"),
            WireError::BadValue(what) => write!(f, "bad field value: {what}"),
            WireError::TrailingBytes(n) => write!(f, "{n} trailing bytes after message"),
        }
    }
}

impl std::error::Error for WireError {}

/// Typed error surface of the serving path, as carried by an
/// [`Reply::Error`] frame. The four service outcomes a caller must be
/// able to tell apart — admission rejection, cancellation, deadline,
/// refused queue — are distinct codes, mirroring the in-process
/// taxonomy (`Rejected`, `Interrupted::{Cancelled, DeadlineExceeded}`,
/// the queue-closed submit error).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorCode {
    /// Admission control refused the job (`coordinator::Rejected`).
    AdmissionRejected,
    /// The job was cancelled (`Interrupted::Cancelled`).
    Cancelled,
    /// The job's deadline expired (`Interrupted::DeadlineExceeded`).
    DeadlineExceeded,
    /// The queue refused the submission — the service is draining for
    /// shutdown. (A merely *full* queue never errors: the connection
    /// handler blocks on the bounded queue exactly like an in-process
    /// caller; see DESIGN.md "Wire protocol & connection backpressure".)
    QueueRefused,
    /// No job with the requested id (never submitted, or its retained
    /// result aged out of the retention window).
    NotFound,
    /// The job exists but has not completed yet (poll again).
    NotReady,
    /// The request was malformed (decode failure or out-of-domain
    /// field).
    BadRequest,
    /// The server is at its connection limit.
    TooManyConnections,
    /// Anything else (engine failure, I/O error, panic-contained job).
    Internal,
}

impl ErrorCode {
    /// All codes, for sweep tests.
    pub const ALL: [ErrorCode; 9] = [
        ErrorCode::AdmissionRejected,
        ErrorCode::Cancelled,
        ErrorCode::DeadlineExceeded,
        ErrorCode::QueueRefused,
        ErrorCode::NotFound,
        ErrorCode::NotReady,
        ErrorCode::BadRequest,
        ErrorCode::TooManyConnections,
        ErrorCode::Internal,
    ];

    pub fn as_u8(self) -> u8 {
        match self {
            ErrorCode::AdmissionRejected => 1,
            ErrorCode::Cancelled => 2,
            ErrorCode::DeadlineExceeded => 3,
            ErrorCode::QueueRefused => 4,
            ErrorCode::NotFound => 5,
            ErrorCode::NotReady => 6,
            ErrorCode::BadRequest => 7,
            ErrorCode::TooManyConnections => 8,
            ErrorCode::Internal => 9,
        }
    }

    pub fn from_u8(b: u8) -> Result<ErrorCode, WireError> {
        Ok(match b {
            1 => ErrorCode::AdmissionRejected,
            2 => ErrorCode::Cancelled,
            3 => ErrorCode::DeadlineExceeded,
            4 => ErrorCode::QueueRefused,
            5 => ErrorCode::NotFound,
            6 => ErrorCode::NotReady,
            7 => ErrorCode::BadRequest,
            8 => ErrorCode::TooManyConnections,
            9 => ErrorCode::Internal,
            _ => return Err(WireError::BadValue("error code")),
        })
    }
}

/// Classify a serving-path error into its wire code. The queue-closed
/// submit failure is an `anyhow!` string in the existing taxonomy, so
/// it is matched on the exact message the service raises.
pub fn error_code_for(e: &anyhow::Error) -> ErrorCode {
    use crate::coordinator::{Interrupted, Rejected};
    if e.downcast_ref::<Rejected>().is_some() {
        return ErrorCode::AdmissionRejected;
    }
    if let Some(i) = e.downcast_ref::<Interrupted>() {
        return match i {
            Interrupted::Cancelled => ErrorCode::Cancelled,
            Interrupted::DeadlineExceeded => ErrorCode::DeadlineExceeded,
        };
    }
    if e.to_string() == "service is shut down" {
        return ErrorCode::QueueRefused;
    }
    ErrorCode::Internal
}

/// Canonical FCM parameters on the wire (fixed header of a submit).
/// `usize` fields travel as `u32` — a cluster count or iteration cap
/// beyond 2³² is not a real configuration.
fn put_params(w: &mut Vec<u8>, p: &FcmParams) {
    put_u32(w, p.clusters as u32);
    put_f32(w, p.m);
    put_f32(w, p.epsilon);
    put_u32(w, p.max_iters as u32);
    put_u64(w, p.seed);
}

fn get_params(r: &mut Reader<'_>) -> Result<FcmParams, WireError> {
    Ok(FcmParams {
        clusters: r.u32()? as usize,
        m: r.f32()?,
        epsilon: r.f32()?,
        max_iters: r.u32()? as usize,
        seed: r.u64()?,
    })
}

/// The input a submit carries.
#[derive(Clone, Debug, PartialEq)]
pub enum SubmitPayload {
    /// 8-bit grayscale image, row-major.
    Image { width: u32, height: u32, pixels: Vec<u8> },
    /// 8-bit voxel volume, z-major.
    Volume { width: u32, height: u32, depth: u32, voxels: Vec<u8> },
    /// File-backed streamed volume: the frame carries **paths, not
    /// voxels** — server-side shared storage does the byte transport,
    /// which is what lets a volume larger than any frame (or any RAM)
    /// ride a 100-byte submit.
    Stream {
        input: String,
        mask: Option<String>,
        output: String,
        tile_slices: u32,
        prefetch: bool,
    },
}

/// A segmentation job as submitted over the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct SubmitJob {
    pub engine: Engine,
    pub priority: Priority,
    pub params: FcmParams,
    pub payload: SubmitPayload,
}

/// Client → server messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Liveness probe.
    Ping,
    /// Submit a job; answered with [`Reply::Submitted`] (or an error).
    Submit(SubmitJob),
    /// Poll a job's state.
    Status { id: u64 },
    /// Fetch a completed job's result.
    Fetch { id: u64 },
    /// Fetch the service metrics exposition.
    Metrics,
    /// Ask the server to drain and shut down gracefully.
    Shutdown,
}

/// Lifecycle state carried by a [`Reply::Status`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Queued or executing.
    Pending,
    /// Completed; the result is retained for fetching.
    Done,
    /// Failed; fetching yields the typed error.
    Failed,
}

impl JobState {
    fn as_u8(self) -> u8 {
        match self {
            JobState::Pending => 0,
            JobState::Done => 1,
            JobState::Failed => 2,
        }
    }

    fn from_u8(b: u8) -> Result<JobState, WireError> {
        Ok(match b {
            0 => JobState::Pending,
            1 => JobState::Done,
            2 => JobState::Failed,
            _ => return Err(WireError::BadValue("job state")),
        })
    }
}

/// A completed job's result on the wire. `shape` carries the submitted
/// raster's dimensions (width, height, depth — depth 1 for images, all
/// zero when unknown) so a fetching client can render labels to the
/// same RVOL bytes the in-process CLI writes; streamed jobs ship empty
/// `labels` (the bytes live in the job's server-side output file).
#[derive(Clone, Debug, PartialEq)]
pub struct WireResult {
    pub id: u64,
    pub labels: Vec<u8>,
    pub centers: Vec<f32>,
    pub iterations: u32,
    pub converged: bool,
    pub engine: Engine,
    pub cached: bool,
    pub shape: (u32, u32, u32),
    pub clusters: u32,
    pub queue_wait_s: f64,
    pub service_s: f64,
}

/// Server → client messages.
#[derive(Clone, Debug, PartialEq)]
pub enum Reply {
    Pong,
    Submitted { id: u64 },
    Status { id: u64, state: JobState },
    Result(Box<WireResult>),
    Metrics { prometheus: String },
    ShutdownAck,
    /// Typed failure; `code` round-trips the service taxonomy.
    Error { code: ErrorCode, message: String },
}

// ---- request/reply message tags ----

const TAG_PING: u8 = 0x01;
const TAG_SUBMIT: u8 = 0x02;
const TAG_STATUS: u8 = 0x03;
const TAG_FETCH: u8 = 0x04;
const TAG_METRICS: u8 = 0x05;
const TAG_SHUTDOWN: u8 = 0x06;

const TAG_PONG: u8 = 0x81;
const TAG_SUBMITTED: u8 = 0x82;
const TAG_STATUS_REPLY: u8 = 0x83;
const TAG_RESULT: u8 = 0x84;
const TAG_METRICS_REPLY: u8 = 0x85;
const TAG_SHUTDOWN_ACK: u8 = 0x86;
const TAG_ERROR: u8 = 0xFF;

// ---- submit payload kinds ----

const KIND_IMAGE: u8 = 0;
const KIND_VOLUME: u8 = 1;
const KIND_STREAM: u8 = 2;

// ---- primitive put/get ----

fn put_u8(w: &mut Vec<u8>, v: u8) {
    w.push(v);
}

fn put_u32(w: &mut Vec<u8>, v: u32) {
    w.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(w: &mut Vec<u8>, v: u64) {
    w.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(w: &mut Vec<u8>, v: f32) {
    w.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(w: &mut Vec<u8>, v: f64) {
    w.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(w: &mut Vec<u8>, b: &[u8]) {
    put_u32(w, b.len() as u32);
    w.extend_from_slice(b);
}

fn put_str(w: &mut Vec<u8>, s: &str) {
    put_bytes(w, s.as_bytes());
}

/// Bounds-checked cursor over one frame's payload.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::BadValue("length overflow"))?;
        if end > self.buf.len() {
            return Err(WireError::Truncated { needed: end, have: self.buf.len() });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, WireError> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> Result<Vec<u8>, WireError> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn string(&mut self) -> Result<String, WireError> {
        String::from_utf8(self.bytes()?).map_err(|_| WireError::BadValue("non-UTF-8 string"))
    }

    fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::TrailingBytes(self.buf.len() - self.pos));
        }
        Ok(())
    }
}

fn engine_from_u8(b: u8) -> Result<Engine, WireError> {
    Engine::ALL
        .get(b as usize)
        .copied()
        .ok_or(WireError::BadValue("engine"))
}

fn priority_from_u8(b: u8) -> Result<Priority, WireError> {
    Ok(match b {
        0 => Priority::High,
        1 => Priority::Normal,
        2 => Priority::Low,
        _ => return Err(WireError::BadValue("priority")),
    })
}

// ---- message codec ----

/// Encode a request into one frame payload (tag + body).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut w = Vec::new();
    match req {
        Request::Ping => put_u8(&mut w, TAG_PING),
        Request::Submit(job) => {
            put_u8(&mut w, TAG_SUBMIT);
            let kind = match &job.payload {
                SubmitPayload::Image { .. } => KIND_IMAGE,
                SubmitPayload::Volume { .. } => KIND_VOLUME,
                SubmitPayload::Stream { .. } => KIND_STREAM,
            };
            put_u8(&mut w, kind);
            put_u8(&mut w, job.engine.index() as u8);
            put_u8(&mut w, job.priority.rank());
            put_params(&mut w, &job.params);
            match &job.payload {
                SubmitPayload::Image { width, height, pixels } => {
                    put_u32(&mut w, *width);
                    put_u32(&mut w, *height);
                    put_bytes(&mut w, pixels);
                }
                SubmitPayload::Volume { width, height, depth, voxels } => {
                    put_u32(&mut w, *width);
                    put_u32(&mut w, *height);
                    put_u32(&mut w, *depth);
                    put_bytes(&mut w, voxels);
                }
                SubmitPayload::Stream { input, mask, output, tile_slices, prefetch } => {
                    put_str(&mut w, input);
                    match mask {
                        Some(m) => {
                            put_u8(&mut w, 1);
                            put_str(&mut w, m);
                        }
                        None => put_u8(&mut w, 0),
                    }
                    put_str(&mut w, output);
                    put_u32(&mut w, *tile_slices);
                    put_u8(&mut w, u8::from(*prefetch));
                }
            }
        }
        Request::Status { id } => {
            put_u8(&mut w, TAG_STATUS);
            put_u64(&mut w, *id);
        }
        Request::Fetch { id } => {
            put_u8(&mut w, TAG_FETCH);
            put_u64(&mut w, *id);
        }
        Request::Metrics => put_u8(&mut w, TAG_METRICS),
        Request::Shutdown => put_u8(&mut w, TAG_SHUTDOWN),
    }
    w
}

/// Decode one frame payload into a request. Total: every malformed
/// input is a typed [`WireError`].
pub fn decode_request(buf: &[u8]) -> Result<Request, WireError> {
    let mut r = Reader::new(buf);
    let req = match r.u8()? {
        TAG_PING => Request::Ping,
        TAG_SUBMIT => {
            let kind = r.u8()?;
            let engine = engine_from_u8(r.u8()?)?;
            let priority = priority_from_u8(r.u8()?)?;
            let params = get_params(&mut r)?;
            let payload = match kind {
                KIND_IMAGE => {
                    let width = r.u32()?;
                    let height = r.u32()?;
                    let pixels = r.bytes()?;
                    if pixels.len() as u64 != u64::from(width) * u64::from(height) {
                        return Err(WireError::BadValue("image pixel count"));
                    }
                    SubmitPayload::Image { width, height, pixels }
                }
                KIND_VOLUME => {
                    let width = r.u32()?;
                    let height = r.u32()?;
                    let depth = r.u32()?;
                    let voxels = r.bytes()?;
                    let expect = u64::from(width) * u64::from(height) * u64::from(depth);
                    if voxels.len() as u64 != expect {
                        return Err(WireError::BadValue("volume voxel count"));
                    }
                    SubmitPayload::Volume { width, height, depth, voxels }
                }
                KIND_STREAM => {
                    let input = r.string()?;
                    let mask = match r.u8()? {
                        0 => None,
                        1 => Some(r.string()?),
                        _ => return Err(WireError::BadValue("mask flag")),
                    };
                    let output = r.string()?;
                    let tile_slices = r.u32()?;
                    let prefetch = match r.u8()? {
                        0 => false,
                        1 => true,
                        _ => return Err(WireError::BadValue("prefetch flag")),
                    };
                    SubmitPayload::Stream { input, mask, output, tile_slices, prefetch }
                }
                _ => return Err(WireError::BadValue("submit kind")),
            };
            Request::Submit(SubmitJob { engine, priority, params, payload })
        }
        TAG_STATUS => Request::Status { id: r.u64()? },
        TAG_FETCH => Request::Fetch { id: r.u64()? },
        TAG_METRICS => Request::Metrics,
        TAG_SHUTDOWN => Request::Shutdown,
        t => return Err(WireError::UnknownTag(t)),
    };
    r.finish()?;
    Ok(req)
}

/// Encode a reply into one frame payload (tag + body).
pub fn encode_reply(reply: &Reply) -> Vec<u8> {
    let mut w = Vec::new();
    match reply {
        Reply::Pong => put_u8(&mut w, TAG_PONG),
        Reply::Submitted { id } => {
            put_u8(&mut w, TAG_SUBMITTED);
            put_u64(&mut w, *id);
        }
        Reply::Status { id, state } => {
            put_u8(&mut w, TAG_STATUS_REPLY);
            put_u64(&mut w, *id);
            put_u8(&mut w, state.as_u8());
        }
        Reply::Result(res) => {
            put_u8(&mut w, TAG_RESULT);
            put_u64(&mut w, res.id);
            put_bytes(&mut w, &res.labels);
            put_u32(&mut w, res.centers.len() as u32);
            for c in &res.centers {
                put_f32(&mut w, *c);
            }
            put_u32(&mut w, res.iterations);
            put_u8(&mut w, u8::from(res.converged));
            put_u8(&mut w, res.engine.index() as u8);
            put_u8(&mut w, u8::from(res.cached));
            put_u32(&mut w, res.shape.0);
            put_u32(&mut w, res.shape.1);
            put_u32(&mut w, res.shape.2);
            put_u32(&mut w, res.clusters);
            put_f64(&mut w, res.queue_wait_s);
            put_f64(&mut w, res.service_s);
        }
        Reply::Metrics { prometheus } => {
            put_u8(&mut w, TAG_METRICS_REPLY);
            put_str(&mut w, prometheus);
        }
        Reply::ShutdownAck => put_u8(&mut w, TAG_SHUTDOWN_ACK),
        Reply::Error { code, message } => {
            put_u8(&mut w, TAG_ERROR);
            put_u8(&mut w, code.as_u8());
            put_str(&mut w, message);
        }
    }
    w
}

/// Decode one frame payload into a reply.
pub fn decode_reply(buf: &[u8]) -> Result<Reply, WireError> {
    let mut r = Reader::new(buf);
    let reply = match r.u8()? {
        TAG_PONG => Reply::Pong,
        TAG_SUBMITTED => Reply::Submitted { id: r.u64()? },
        TAG_STATUS_REPLY => Reply::Status {
            id: r.u64()?,
            state: JobState::from_u8(r.u8()?)?,
        },
        TAG_RESULT => {
            let id = r.u64()?;
            let labels = r.bytes()?;
            let n = r.u32()? as usize;
            // Bounds-check before reserving: a hostile count cannot
            // allocate past the frame it arrived in.
            if n > buf.len() / 4 {
                return Err(WireError::BadValue("center count"));
            }
            let mut centers = Vec::with_capacity(n);
            for _ in 0..n {
                centers.push(r.f32()?);
            }
            Reply::Result(Box::new(WireResult {
                id,
                labels,
                centers,
                iterations: r.u32()?,
                converged: r.u8()? != 0,
                engine: engine_from_u8(r.u8()?)?,
                cached: r.u8()? != 0,
                shape: (r.u32()?, r.u32()?, r.u32()?),
                clusters: r.u32()?,
                queue_wait_s: r.f64()?,
                service_s: r.f64()?,
            }))
        }
        TAG_METRICS_REPLY => Reply::Metrics { prometheus: r.string()? },
        TAG_SHUTDOWN_ACK => Reply::ShutdownAck,
        TAG_ERROR => Reply::Error {
            code: ErrorCode::from_u8(r.u8()?)?,
            message: r.string()?,
        },
        t => return Err(WireError::UnknownTag(t)),
    };
    r.finish()?;
    Ok(reply)
}

// ---- frame I/O ----

/// Write one frame (length prefix + payload). Returns the total bytes
/// put on the wire.
pub fn write_frame(w: &mut impl std::io::Write, payload: &[u8]) -> std::io::Result<u64> {
    let len = u32::try_from(payload.len()).map_err(|_| {
        std::io::Error::new(std::io::ErrorKind::InvalidInput, WireError::Oversized {
            declared: u32::MAX,
        })
    })?;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            WireError::Oversized { declared: len },
        ));
    }
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(4 + payload.len() as u64)
}

/// Read one frame's payload. `Ok(None)` on a clean EOF at a frame
/// boundary (the peer closed between requests); an EOF *inside* a frame
/// — mid-length or mid-payload — is an `UnexpectedEof` error, and a
/// declared length beyond [`MAX_FRAME`] is rejected (`InvalidData`
/// wrapping [`WireError::Oversized`]) before any allocation.
pub fn read_frame(r: &mut impl std::io::Read) -> std::io::Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    // First byte distinguishes clean close from mid-frame disconnect.
    match r.read(&mut len_buf[..1])? {
        0 => return Ok(None),
        _ => r.read_exact(&mut len_buf[1..])?,
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            WireError::Oversized { declared: len },
        ));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    Ok(Some(payload))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_request(req: Request) {
        let enc = encode_request(&req);
        assert_eq!(decode_request(&enc).unwrap(), req, "request round-trip");
    }

    fn roundtrip_reply(reply: Reply) {
        let enc = encode_reply(&reply);
        assert_eq!(decode_reply(&enc).unwrap(), reply, "reply round-trip");
    }

    #[test]
    fn requests_roundtrip() {
        roundtrip_request(Request::Ping);
        roundtrip_request(Request::Status { id: 42 });
        roundtrip_request(Request::Fetch { id: u64::MAX });
        roundtrip_request(Request::Metrics);
        roundtrip_request(Request::Shutdown);
        roundtrip_request(Request::Submit(SubmitJob {
            engine: Engine::Histogram,
            priority: Priority::High,
            params: FcmParams { clusters: 3, m: 2.5, epsilon: 1e-3, max_iters: 77, seed: 9 },
            payload: SubmitPayload::Image { width: 2, height: 3, pixels: vec![1, 2, 3, 4, 5, 6] },
        }));
        roundtrip_request(Request::Submit(SubmitJob {
            engine: Engine::Parallel,
            priority: Priority::Low,
            params: FcmParams::default(),
            payload: SubmitPayload::Volume {
                width: 2,
                height: 2,
                depth: 2,
                voxels: vec![0; 8],
            },
        }));
        roundtrip_request(Request::Submit(SubmitJob {
            engine: Engine::Spatial,
            priority: Priority::Normal,
            params: FcmParams::default(),
            payload: SubmitPayload::Stream {
                input: "/data/in#3.rvol".into(),
                mask: Some("/data/mask.rvol".into()),
                output: "/data/out.rvol".into(),
                tile_slices: 8,
                prefetch: true,
            },
        }));
        // Maskless stream too (exercises the 0 flag).
        roundtrip_request(Request::Submit(SubmitJob {
            engine: Engine::Sequential,
            priority: Priority::Normal,
            params: FcmParams::default(),
            payload: SubmitPayload::Stream {
                input: "in.rvol".into(),
                mask: None,
                output: "out.rvol".into(),
                tile_slices: 1,
                prefetch: false,
            },
        }));
    }

    #[test]
    fn replies_roundtrip() {
        roundtrip_reply(Reply::Pong);
        roundtrip_reply(Reply::Submitted { id: 7 });
        for state in [JobState::Pending, JobState::Done, JobState::Failed] {
            roundtrip_reply(Reply::Status { id: 1, state });
        }
        roundtrip_reply(Reply::Result(Box::new(WireResult {
            id: 3,
            labels: vec![0, 1, 2, 1],
            centers: vec![10.0, 100.0, 200.0],
            iterations: 25,
            converged: true,
            engine: Engine::Histogram,
            cached: false,
            shape: (2, 2, 1),
            clusters: 3,
            queue_wait_s: 0.125,
            service_s: 1.5,
        })));
        roundtrip_reply(Reply::Metrics { prometheus: "repro_x 1\n".into() });
        roundtrip_reply(Reply::ShutdownAck);
        for code in ErrorCode::ALL {
            roundtrip_reply(Reply::Error { code, message: format!("why {code:?}") });
        }
    }

    #[test]
    fn error_codes_are_distinct_and_roundtrip() {
        let mut seen = std::collections::HashSet::new();
        for code in ErrorCode::ALL {
            assert!(seen.insert(code.as_u8()), "duplicate wire byte for {code:?}");
            assert_eq!(ErrorCode::from_u8(code.as_u8()).unwrap(), code);
        }
        assert!(ErrorCode::from_u8(0).is_err());
        assert!(ErrorCode::from_u8(200).is_err());
    }

    #[test]
    fn taxonomy_maps_to_distinct_codes() {
        use crate::coordinator::{Interrupted, Rejected};
        let rejected = anyhow::Error::new(Rejected { would_exceed: 2, budget: 1 });
        let cancelled = anyhow::Error::new(Interrupted::Cancelled);
        let deadline = anyhow::Error::new(Interrupted::DeadlineExceeded);
        let closed = anyhow::anyhow!("service is shut down");
        let other = anyhow::anyhow!("disk on fire");
        assert_eq!(error_code_for(&rejected), ErrorCode::AdmissionRejected);
        assert_eq!(error_code_for(&cancelled), ErrorCode::Cancelled);
        assert_eq!(error_code_for(&deadline), ErrorCode::DeadlineExceeded);
        assert_eq!(error_code_for(&closed), ErrorCode::QueueRefused);
        assert_eq!(error_code_for(&other), ErrorCode::Internal);
        // Context-wrapped taxonomy errors still classify (downcast walks
        // the chain).
        let wrapped = anyhow::Error::new(Interrupted::DeadlineExceeded).context("while serving");
        assert_eq!(error_code_for(&wrapped), ErrorCode::DeadlineExceeded);
    }

    #[test]
    fn malformed_payloads_are_typed_errors() {
        // Empty payload: no tag.
        assert!(matches!(decode_request(&[]), Err(WireError::Truncated { .. })));
        // Unknown tags, both directions.
        assert_eq!(decode_request(&[0x70]), Err(WireError::UnknownTag(0x70)));
        assert_eq!(decode_reply(&[0x02]), Err(WireError::UnknownTag(0x02)));
        // Truncated fixed header (status id cut short).
        let mut enc = encode_request(&Request::Status { id: 77 });
        enc.truncate(5);
        assert!(matches!(decode_request(&enc), Err(WireError::Truncated { .. })));
        // Trailing garbage after a complete message.
        let mut enc = encode_request(&Request::Ping);
        enc.push(0xAB);
        assert_eq!(decode_request(&enc), Err(WireError::TrailingBytes(1)));
        // Bad enum bytes.
        let mut enc = encode_request(&Request::Submit(SubmitJob {
            engine: Engine::Parallel,
            priority: Priority::Normal,
            params: FcmParams::default(),
            payload: SubmitPayload::Image { width: 1, height: 1, pixels: vec![0] },
        }));
        enc[2] = 99; // engine byte
        assert_eq!(decode_request(&enc), Err(WireError::BadValue("engine")));
        // Shape/byte-count mismatch.
        let mut w = Vec::new();
        w.push(0x02); // submit
        w.push(0); // image
        w.push(Engine::Parallel.index() as u8);
        w.push(Priority::Normal.rank());
        put_params(&mut w, &FcmParams::default());
        put_u32(&mut w, 4); // width
        put_u32(&mut w, 4); // height
        put_bytes(&mut w, &[0u8; 3]); // but only 3 pixels
        assert_eq!(decode_request(&w), Err(WireError::BadValue("image pixel count")));
        // A declared byte-string length far past the payload end.
        let mut w = Vec::new();
        w.push(0x85); // metrics reply
        put_u32(&mut w, u32::MAX); // string "length"
        assert!(matches!(decode_reply(&w), Err(WireError::Truncated { .. })));
    }

    #[test]
    fn frame_io_roundtrips_and_rejects_oversize() {
        let payload = encode_request(&Request::Status { id: 5 });
        let mut wire = Vec::new();
        let n = write_frame(&mut wire, &payload).unwrap();
        assert_eq!(n as usize, wire.len());
        assert_eq!(&wire[..4], &(payload.len() as u32).to_le_bytes());
        let mut cursor = &wire[..];
        let got = read_frame(&mut cursor).unwrap().unwrap();
        assert_eq!(got, payload);
        // Clean EOF at a boundary is None, not an error.
        assert_eq!(read_frame(&mut cursor).unwrap(), None);
        // Oversized declared length is rejected before allocation.
        let mut bad = Vec::new();
        bad.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let err = read_frame(&mut &bad[..]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // Mid-frame EOF (truncated length, truncated payload) errors.
        let err = read_frame(&mut &wire[..2]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        let err = read_frame(&mut &wire[..6]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
        // Writing an oversized payload is refused up front.
        let huge = vec![0u8; MAX_FRAME as usize + 1];
        assert!(write_frame(&mut Vec::new(), &huge).is_err());
    }
}
