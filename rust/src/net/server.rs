//! TCP front door: accept loop, per-connection handlers, and the
//! retained-result store that makes fetch-after-completion work.
//!
//! Threading shape (DESIGN.md "Wire protocol & connection backpressure"):
//! one acceptor thread, one handler thread per connection, one collector
//! thread per submitted job. A handler processes exactly one request at
//! a time; a submit that lands on a full service queue **blocks the
//! handler** inside [`crate::coordinator::Service`]'s bounded-queue
//! push — that block is the remote client's backpressure, byte-for-byte
//! the same mechanism an in-process caller gets. No frames are buffered
//! ahead of the service: a blocked handler simply stops reading its
//! socket, and TCP flow control pushes the wait back to the client.
//!
//! Graceful shutdown (triggered by a wire `Shutdown` request or by the
//! host calling [`Server::shutdown`]): stop accepting, nudge every
//! open connection's read side closed so handlers finish their
//! in-flight request and exit on EOF, join handlers, let the collectors
//! drain (workers keep serving until the service itself shuts down),
//! then run [`crate::coordinator::Service::shutdown`] and hand the
//! final [`Snapshot`] back for the usual metrics exposition.

use super::protocol::{
    decode_request, encode_reply, error_code_for, read_frame, write_frame, ErrorCode, JobState,
    Reply, Request, SubmitJob, SubmitPayload, WireResult,
};
use crate::coordinator::{JobResult, Service, Snapshot, Ticket};
use crate::fcm::FcmParams;
use crate::image::{FeatureVector, GrayImage, VoxelVolume};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a completed job's result stays fetchable. Completed entries
/// past this age are purged opportunistically (on every store touch), so
/// a fire-and-forget submitter cannot grow the map without bound.
pub const DEFAULT_RESULT_TTL: Duration = Duration::from_secs(600);

/// Lifecycle of one retained job entry.
enum EntryState {
    Pending,
    Done(Box<WireResult>),
    Failed { code: ErrorCode, message: String },
}

struct Entry {
    state: EntryState,
    /// When the job reached a terminal state — the TTL clock. `None`
    /// while pending (pending entries never age out; their collector
    /// always resolves them).
    done_at: Option<Instant>,
    /// Raster dimensions captured at submit time. [`JobResult`] carries
    /// no shape, but a fetching client needs one to render labels to
    /// the same RVOL bytes the in-process CLI writes.
    shape: (u32, u32, u32),
    clusters: u32,
}

/// What a fetch/status lookup found.
enum Fetched {
    Missing,
    Pending,
    Done(Box<WireResult>),
    Failed { code: ErrorCode, message: String },
}

/// Retained results keyed by job id, with a TTL on terminal entries.
struct ResultStore {
    entries: Mutex<HashMap<u64, Entry>>,
    ttl: Duration,
}

impl ResultStore {
    fn new(ttl: Duration) -> ResultStore {
        ResultStore { entries: Mutex::new(HashMap::new()), ttl }
    }

    /// Drop terminal entries older than the TTL. Called under the lock
    /// on every touch — the map is bounded by in-flight jobs plus one
    /// TTL window of completions, so the sweep stays cheap.
    fn purge(&self, entries: &mut HashMap<u64, Entry>, now: Instant) {
        entries.retain(|_, e| match e.done_at {
            Some(at) => now.duration_since(at) < self.ttl,
            None => true,
        });
    }

    fn insert_pending(&self, id: u64, shape: (u32, u32, u32), clusters: u32) {
        let mut g = self.entries.lock().unwrap();
        let now = Instant::now();
        self.purge(&mut g, now);
        g.insert(id, Entry { state: EntryState::Pending, done_at: None, shape, clusters });
    }

    fn complete(&self, id: u64, res: JobResult) {
        let mut g = self.entries.lock().unwrap();
        let Some(e) = g.get_mut(&id) else { return };
        let wire = WireResult {
            id,
            labels: res.labels,
            centers: res.centers,
            iterations: res.iterations as u32,
            converged: res.converged,
            engine: res.engine,
            cached: res.cached,
            shape: e.shape,
            clusters: e.clusters,
            queue_wait_s: res.queue_wait_s,
            service_s: res.service_s,
        };
        e.state = EntryState::Done(Box::new(wire));
        e.done_at = Some(Instant::now());
    }

    fn fail(&self, id: u64, code: ErrorCode, message: String) {
        let mut g = self.entries.lock().unwrap();
        let Some(e) = g.get_mut(&id) else { return };
        e.state = EntryState::Failed { code, message };
        e.done_at = Some(Instant::now());
    }

    /// Look up an entry. Done results are **cloned out and retained**
    /// (until the TTL), so a fetch can be repeated — a dropped reply
    /// frame does not orphan the result.
    fn get(&self, id: u64) -> Fetched {
        let mut g = self.entries.lock().unwrap();
        let now = Instant::now();
        self.purge(&mut g, now);
        match g.get(&id) {
            None => Fetched::Missing,
            Some(e) => match &e.state {
                EntryState::Pending => Fetched::Pending,
                EntryState::Done(r) => Fetched::Done(r.clone()),
                EntryState::Failed { code, message } => {
                    Fetched::Failed { code: *code, message: message.clone() }
                }
            },
        }
    }
}

/// State shared by the acceptor, every handler, and every collector.
struct Shared {
    service: Arc<Service>,
    store: ResultStore,
    /// Read-side clones of every live connection, for the shutdown
    /// nudge. Keyed by a per-connection id so handlers deregister
    /// exactly their own entry.
    conns: Mutex<HashMap<u64, TcpStream>>,
    collectors: Mutex<Vec<JoinHandle<()>>>,
    stopping: AtomicBool,
    /// Set by a wire `Shutdown` request; the host blocks on this in
    /// [`Server::wait_for_shutdown_request`].
    shutdown_requested: (Mutex<bool>, Condvar),
    max_connections: usize,
}

impl Shared {
    fn metrics(&self) -> &crate::coordinator::Metrics {
        &self.service.metrics
    }

    /// Spawn the collector that parks on the ticket and records the
    /// job's terminal state in the store.
    fn spawn_collector(self: &Arc<Self>, id: u64, ticket: Ticket) {
        let shared = Arc::clone(self);
        let h = std::thread::Builder::new()
            .name(format!("net-collect-{id}"))
            .spawn(move || match ticket.wait() {
                Ok(res) => shared.store.complete(id, res),
                Err(e) => shared.store.fail(id, error_code_for(&e), format!("{e:#}")),
            })
            .expect("spawning collector");
        self.collectors.lock().unwrap().push(h);
    }

    /// Serve one decoded non-submit request. Infallible by
    /// construction: every failure becomes a typed [`Reply::Error`].
    /// (Submits go through [`Shared::submit_and_collect`] in the
    /// handler loop, which also spawns the job's collector.)
    fn process(self: &Arc<Self>, req: Request) -> Reply {
        match req {
            Request::Ping => Reply::Pong,
            Request::Submit(_) => Reply::Error {
                code: ErrorCode::Internal,
                message: "submit routed past the collector path".into(),
            },
            Request::Status { id } => match self.store.get(id) {
                Fetched::Missing => Reply::Error {
                    code: ErrorCode::NotFound,
                    message: format!("no job {id} (never submitted, or its result aged out)"),
                },
                Fetched::Pending => Reply::Status { id, state: JobState::Pending },
                Fetched::Done(_) => Reply::Status { id, state: JobState::Done },
                Fetched::Failed { .. } => Reply::Status { id, state: JobState::Failed },
            },
            Request::Fetch { id } => match self.store.get(id) {
                Fetched::Missing => Reply::Error {
                    code: ErrorCode::NotFound,
                    message: format!("no job {id} (never submitted, or its result aged out)"),
                },
                Fetched::Pending => Reply::Error {
                    code: ErrorCode::NotReady,
                    message: format!("job {id} is still pending; poll status"),
                },
                Fetched::Done(r) => Reply::Result(r),
                Fetched::Failed { code, message } => Reply::Error { code, message },
            },
            Request::Metrics => Reply::Metrics {
                prometheus: self.service.metrics.snapshot().to_prometheus(),
            },
            Request::Shutdown => {
                let (flag, cv) = &self.shutdown_requested;
                *flag.lock().unwrap() = true;
                cv.notify_all();
                Reply::ShutdownAck
            }
        }
    }
}

impl Shared {
    /// Submit one wire job onto the service, retain a pending store
    /// entry for it (shape + clusters captured here — [`JobResult`]
    /// carries neither), and spawn its collector. The `submit_*` call
    /// is where a full service queue blocks — the handler, and through
    /// TCP flow control the remote client, waits right here.
    fn submit_and_collect(self: &Arc<Self>, job: SubmitJob) -> Result<u64> {
        let SubmitJob { engine, priority, params, payload } = job;
        let clusters = params.clusters as u32;
        let (ticket, shape) = match payload {
            SubmitPayload::Image { width, height, pixels } => {
                let img = GrayImage::from_pixels(width as usize, height as usize, pixels);
                let t = self.service.submit_with_priority(
                    FeatureVector::from_image(&img),
                    params,
                    engine,
                    priority,
                )?;
                (t, (width, height, 1))
            }
            SubmitPayload::Volume { width, height, depth, voxels } => {
                let vol = VoxelVolume::from_voxels(
                    width as usize,
                    height as usize,
                    depth as usize,
                    voxels,
                );
                let t = self.service.submit_volume_with_priority(vol, params, engine, priority)?;
                (t, (width, height, depth))
            }
            SubmitPayload::Stream { input, mask, output, tile_slices, prefetch } => {
                let spec = crate::coordinator::StreamVolumeJob {
                    input: input.into(),
                    mask: mask.map(Into::into),
                    output: output.into(),
                    tile_slices: tile_slices as usize,
                    prefetch,
                    fault: None,
                };
                let t = self.service.submit_volume_streamed_with_priority(
                    spec, params, engine, priority,
                )?;
                (t, (0, 0, 0))
            }
        };
        let id = ticket.id;
        self.store.insert_pending(id, shape, clusters);
        self.spawn_collector(id, ticket);
        Ok(id)
    }
}

/// One connection's serve loop: read frame → decode → process → reply,
/// strictly one request in flight. Exits on clean EOF, on any socket
/// error, or when shutdown closes the read side under it.
fn handle_conn(shared: Arc<Shared>, mut stream: TcpStream, conn_id: u64) {
    shared.metrics().net_connection();
    loop {
        let payload = match read_frame(&mut stream) {
            Ok(Some(p)) => p,
            Ok(None) => break, // clean close at a frame boundary
            Err(_) => {
                // Mid-frame disconnect, oversized declared length, or
                // the shutdown nudge. Count it as a wire error unless
                // we are the ones tearing the connection down.
                if !shared.stopping.load(Ordering::SeqCst) {
                    shared.metrics().net_error();
                }
                break;
            }
        };
        shared.metrics().net_frame_in(4 + payload.len() as u64);
        let reply = match decode_request(&payload) {
            Ok(Request::Submit(job)) => match shared.submit_and_collect(job) {
                Ok(id) => Reply::Submitted { id },
                Err(e) => {
                    shared.metrics().net_error();
                    Reply::Error { code: error_code_for(&e), message: format!("{e:#}") }
                }
            },
            Ok(req) => shared.process(req),
            Err(e) => {
                shared.metrics().net_error();
                Reply::Error { code: ErrorCode::BadRequest, message: e.to_string() }
            }
        };
        let shutting_down = matches!(reply, Reply::ShutdownAck);
        match write_frame(&mut stream, &encode_reply(&reply)) {
            Ok(n) => shared.metrics().net_frame_out(n),
            Err(_) => {
                shared.metrics().net_error();
                break;
            }
        }
        if shutting_down {
            break;
        }
    }
    shared.conns.lock().unwrap().remove(&conn_id);
}

/// The running TCP server. Construct with [`Server::bind`]; tear down
/// with [`Server::shutdown`], which drains everything and returns the
/// service's final metrics snapshot.
pub struct Server {
    shared: Arc<Shared>,
    local_addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port — read it back via
    /// [`Server::local_addr`]) and start accepting, serving jobs on
    /// `service`. Results are retained for [`DEFAULT_RESULT_TTL`].
    pub fn bind(service: Arc<Service>, addr: &str, max_connections: usize) -> Result<Server> {
        Server::bind_with_retention(service, addr, max_connections, DEFAULT_RESULT_TTL)
    }

    /// [`Server::bind`] with an explicit result-retention TTL (tests
    /// shrink it to observe expiry).
    pub fn bind_with_retention(
        service: Arc<Service>,
        addr: &str,
        max_connections: usize,
        ttl: Duration,
    ) -> Result<Server> {
        anyhow::ensure!(max_connections >= 1, "max_connections must be >= 1");
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        let local_addr = listener.local_addr()?;
        let shared = Arc::new(Shared {
            service,
            store: ResultStore::new(ttl),
            conns: Mutex::new(HashMap::new()),
            collectors: Mutex::new(Vec::new()),
            stopping: AtomicBool::new(false),
            shutdown_requested: (Mutex::new(false), Condvar::new()),
            max_connections,
        });
        let handlers = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let shared = Arc::clone(&shared);
            let handlers = Arc::clone(&handlers);
            std::thread::Builder::new()
                .name("net-accept".into())
                .spawn(move || accept_loop(listener, shared, handlers))
                .expect("spawning acceptor")
        };
        Ok(Server { shared, local_addr, acceptor: Some(acceptor), handlers })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Block until some client sends a wire `Shutdown` request. The
    /// serve CLI parks here, then runs [`Server::shutdown`].
    pub fn wait_for_shutdown_request(&self) {
        let (flag, cv) = &self.shared.shutdown_requested;
        let mut g = flag.lock().unwrap();
        while !*g {
            g = cv.wait(g).unwrap();
        }
    }

    /// Has a wire `Shutdown` request arrived? (Non-blocking peek, for
    /// hosts that interleave the wait with periodic work.)
    pub fn shutdown_requested(&self) -> bool {
        *self.shared.shutdown_requested.0.lock().unwrap()
    }

    /// Graceful teardown: stop accepting, nudge open connections closed
    /// (handlers finish their in-flight request — a reply mid-write is
    /// never cut), join handlers, drain the per-job collectors, then
    /// shut the service itself down and return its final snapshot.
    pub fn shutdown(mut self) -> Result<Snapshot> {
        self.shared.stopping.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway self-connection.
        let _ = TcpStream::connect(self.local_addr);
        if let Some(a) = self.acceptor.take() {
            a.join().map_err(|_| anyhow!("acceptor panicked"))?;
        }
        // Close the read side of every live connection: each handler
        // finishes the request it is processing, writes its reply, then
        // sees EOF and exits.
        for (_, conn) in self.shared.conns.lock().unwrap().iter() {
            let _ = conn.shutdown(Shutdown::Read);
        }
        let handlers = std::mem::take(&mut *self.handlers.lock().unwrap());
        for h in handlers {
            let _ = h.join();
        }
        // Collectors resolve as the still-running workers finish each
        // submitted job; joining them is the in-flight drain.
        let collectors = std::mem::take(&mut *self.shared.collectors.lock().unwrap());
        for c in collectors {
            let _ = c.join();
        }
        let Server { shared, .. } = self;
        let shared = Arc::try_unwrap(shared)
            .map_err(|_| anyhow!("connection state still referenced after drain"))?;
        let service = Arc::try_unwrap(shared.service)
            .map_err(|_| anyhow!("service still referenced after drain"))?;
        Ok(service.shutdown())
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<Shared>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let mut next_conn_id: u64 = 0;
    loop {
        let (stream, _) = match listener.accept() {
            Ok(pair) => pair,
            Err(_) => {
                if shared.stopping.load(Ordering::SeqCst) {
                    break;
                }
                continue;
            }
        };
        if shared.stopping.load(Ordering::SeqCst) {
            break; // the self-connect wake (or a late client) — drop it
        }
        // Connection cap: answer with a typed error and close, rather
        // than silently dropping (a client can tell limit from outage).
        if shared.conns.lock().unwrap().len() >= shared.max_connections {
            shared.metrics().net_error();
            let reply = Reply::Error {
                code: ErrorCode::TooManyConnections,
                message: format!("server is at its {}-connection limit", shared.max_connections),
            };
            let mut stream = stream;
            if let Ok(n) = write_frame(&mut stream, &encode_reply(&reply)) {
                shared.metrics().net_frame_out(n);
            }
            continue;
        }
        let conn_id = next_conn_id;
        next_conn_id += 1;
        if let Ok(clone) = stream.try_clone() {
            shared.conns.lock().unwrap().insert(conn_id, clone);
        }
        let shared2 = Arc::clone(&shared);
        let h = std::thread::Builder::new()
            .name(format!("net-conn-{conn_id}"))
            .spawn(move || handle_conn(shared2, stream, conn_id))
            .expect("spawning connection handler");
        handlers.lock().unwrap().push(h);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;

    fn done_result(id: u64) -> JobResult {
        JobResult {
            id,
            labels: vec![0, 1],
            centers: vec![1.0, 2.0],
            iterations: 3,
            converged: true,
            engine: Engine::Parallel,
            queue_wait_s: 0.0,
            service_s: 0.1,
            device: None,
            worker: 0,
            batch_id: 0,
            peak_resident_bytes: None,
            cached: false,
        }
    }

    #[test]
    fn store_lifecycle_pending_done_fetchable_repeatedly() {
        let store = ResultStore::new(Duration::from_secs(60));
        assert!(matches!(store.get(7), Fetched::Missing));
        store.insert_pending(7, (2, 1, 1), 2);
        assert!(matches!(store.get(7), Fetched::Pending));
        store.complete(7, done_result(7));
        // Fetch twice: the entry is retained, not consumed.
        for _ in 0..2 {
            match store.get(7) {
                Fetched::Done(r) => {
                    assert_eq!(r.shape, (2, 1, 1));
                    assert_eq!(r.clusters, 2);
                    assert_eq!(r.labels, vec![0, 1]);
                }
                _ => panic!("expected Done"),
            }
        }
    }

    #[test]
    fn store_records_failures_with_their_code() {
        let store = ResultStore::new(Duration::from_secs(60));
        store.insert_pending(1, (0, 0, 0), 2);
        store.fail(1, ErrorCode::DeadlineExceeded, "job deadline exceeded".into());
        match store.get(1) {
            Fetched::Failed { code, message } => {
                assert_eq!(code, ErrorCode::DeadlineExceeded);
                assert!(message.contains("deadline"));
            }
            _ => panic!("expected Failed"),
        }
    }

    #[test]
    fn store_ttl_purges_terminal_entries_only() {
        let store = ResultStore::new(Duration::from_millis(30));
        store.insert_pending(1, (2, 1, 1), 2);
        store.insert_pending(2, (2, 1, 1), 2);
        store.complete(1, done_result(1));
        std::thread::sleep(Duration::from_millis(60));
        // The done entry aged out; the pending one never does.
        assert!(matches!(store.get(1), Fetched::Missing));
        assert!(matches!(store.get(2), Fetched::Pending));
    }
}
