//! Networked serving front door (DESIGN.md "Wire protocol & connection
//! backpressure"): a dependency-free TCP layer over the in-process
//! [`crate::coordinator::Service`].
//!
//! * [`protocol`] — the framed binary codec (u32-LE length + tagged
//!   payload), shared verbatim by both ends.
//! * [`server`] — accept loop, thread-per-connection handlers, and the
//!   TTL'd result-retention store behind fetch-after-completion.
//! * [`client`] — blocking connector with typed [`client::RemoteError`]
//!   failures mirroring the in-process error taxonomy.
//!
//! The design goal is that a remote caller is indistinguishable from an
//! in-process one: same submit surface, same typed errors (admission
//! rejection, cancellation, deadline, refused queue round-trip as
//! distinct [`protocol::ErrorCode`]s), same backpressure (a full queue
//! blocks the connection handler, and TCP flow control carries the wait
//! to the client), and byte-identical results (`tests/net.rs` pins a
//! remote fetch against the in-process CLI output).

pub mod client;
pub mod protocol;
pub mod server;

pub use client::{Client, RemoteError};
pub use protocol::{
    ErrorCode, JobState, Reply, Request, SubmitJob, SubmitPayload, WireError, WireResult,
    MAX_FRAME,
};
pub use server::{Server, DEFAULT_RESULT_TTL};
