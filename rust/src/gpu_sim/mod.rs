//! GPU execution-model simulator — the testbed substitute (DESIGN.md §3).
//!
//! We have neither a Tesla C2050 nor the paper's Intel i5; this module is
//! the calibrated analytic model of both devices that regenerates the
//! paper's Table 3 and Fig. 8 (and the ablations probing its Section 5.3
//! open questions). Our *own* stack's measured wall-clock is reported
//! separately by the benches so simulated and measured numbers are never
//! conflated.

pub mod cost;
pub mod device;

pub use cost::{CostModel, CALIB_ITERS, PAPER_TABLE3};
pub use device::{DeviceSpec, INTEL_I5_480, TESLA_C2050};
