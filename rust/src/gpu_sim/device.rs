//! Device specifications for the paper's testbed (Table 2 + Section 5.3).

/// Static description of a compute device.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Parallel processing elements (the paper's horizontal line in Fig. 8).
    pub processors: u32,
    /// Peak single-precision GFLOP/s (paper cites 1030 for the C2050 and
    /// 23 for the i5 — their superlinearity argument, Section 5.3).
    pub gflops_peak: f64,
    /// Memory bandwidth GB/s.
    pub mem_bw_gbs: f64,
    /// Last-level cache bytes (Fermi L2 = 768 KiB; i5-480M L3 = 3 MiB).
    pub llc_bytes: usize,
    /// Host<->device transfer bandwidth GB/s (PCIe gen2 x16 effective).
    pub pcie_gbs: f64,
    /// Kernel-launch overhead, seconds.
    pub launch_overhead_s: f64,
    /// CUDA block size used by the paper's kernels (blockDim.x = 128,
    /// inferred from its "1048576/128 << 1" reduction example).
    pub block_dim: u32,
}

/// NVIDIA Tesla C2050 — the paper's GPU (Table 2).
pub const TESLA_C2050: DeviceSpec = DeviceSpec {
    name: "NVIDIA Tesla C2050",
    processors: 448,
    gflops_peak: 1030.0,
    mem_bw_gbs: 144.0,
    llc_bytes: 768 * 1024,
    pcie_gbs: 6.0,
    launch_overhead_s: 5e-6,
    block_dim: 128,
};

/// Intel Core i5-480M — the paper's sequential CPU (Section 5.1).
pub const INTEL_I5_480: DeviceSpec = DeviceSpec {
    name: "Intel Core i5-480M",
    processors: 1,
    gflops_peak: 23.0,
    mem_bw_gbs: 17.1,
    llc_bytes: 3 * 1024 * 1024,
    pcie_gbs: 0.0,
    launch_overhead_s: 0.0,
    block_dim: 1,
};

impl DeviceSpec {
    /// Tree-reduction depth for n elements (Algorithm 2): ceil(log2) steps
    /// inside a block, then a second stage over n/blockDim partials.
    pub fn reduction_steps(&self, n: usize) -> u32 {
        let bd = self.block_dim.max(2) as usize;
        let in_block = (bd as f64).log2().ceil() as u32;
        let partials = n.div_ceil(bd);
        let final_stage = (partials.max(2) as f64).log2().ceil() as u32;
        in_block + final_stage
    }

    /// Host->device transfer seconds for `bytes`.
    pub fn transfer_seconds(&self, bytes: usize) -> f64 {
        if self.pcie_gbs <= 0.0 {
            0.0
        } else {
            bytes as f64 / (self.pcie_gbs * 1e9)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c2050_matches_paper_table2() {
        assert_eq!(TESLA_C2050.processors, 448);
        assert_eq!(TESLA_C2050.gflops_peak, 1030.0);
        assert_eq!(INTEL_I5_480.gflops_peak, 23.0);
    }

    #[test]
    fn reduction_depth_log() {
        // 1M elements, blockDim 128: 7 in-block steps + 13 final-stage.
        let steps = TESLA_C2050.reduction_steps(1 << 20);
        assert_eq!(steps, 7 + 13);
    }

    #[test]
    fn transfer_linear_in_bytes() {
        let t1 = TESLA_C2050.transfer_seconds(1 << 20);
        let t2 = TESLA_C2050.transfer_seconds(2 << 20);
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        assert_eq!(INTEL_I5_480.transfer_seconds(1 << 20), 0.0);
    }
}
