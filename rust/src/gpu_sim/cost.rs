//! Calibrated analytic execution model of the paper's testbed.
//!
//! Purpose (DESIGN.md section 3, substitution 3): we have no Tesla C2050 or
//! i5-480M, so Table 3 / Fig. 8 are regenerated through a cost model whose
//! *structure* comes from the paper's own description (per-pixel kernels,
//! Algorithm-2 tree reduction, host transfers, per-cluster kernel launches)
//! and whose *rates* are calibrated against the paper's published Table 3.
//! The model therefore reproduces the paper's curve shape — superlinear
//! ends, mid-range dip, crossovers at ~110 KB and ~360 KB — and its
//! components can be ablated to probe the paper's Section 5.3 "open
//! questions" (bench `repro bench-ablation`).
//!
//! Components:
//!   sequential: T = I * n * t_px_cpu * cache_penalty(working_set)
//!   parallel:   T = transfer(n) + I * [launches + n * t_px_gpu * occ(n)
//!                   + reduction(n)]
//! where `occ(n)` is an empirical mid-size contention bump calibrated from
//! the paper's own parallel column (their open question #3: the 100-360 KB
//! region loses superlinearity). With the bump disabled the model predicts
//! the monotone curve classical occupancy analysis would give.

use super::device::{DeviceSpec, INTEL_I5_480, TESLA_C2050};

/// FCM iteration arithmetic per pixel (c=4, m=2): distance, u^2 terms,
/// membership ratio sums — about 12 flops per (pixel, cluster) for the
/// center phase plus c^2-ish for the membership phase.
pub fn flops_per_pixel_iter(clusters: usize) -> f64 {
    let c = clusters as f64;
    6.0 * c + 4.0 * c * c
}

/// The paper's Table 3, embedded for calibration + comparison output:
/// (KB, sequential seconds, parallel seconds).
pub const PAPER_TABLE3: [(usize, f64, f64); 14] = [
    (20, 57.0, 0.102),
    (40, 114.0, 0.195),
    (60, 177.0, 0.321),
    (80, 231.0, 0.505),
    (100, 287.0, 0.632),
    (120, 341.0, 0.864),
    (140, 394.0, 0.977),
    (160, 446.0, 0.986),
    (180, 503.0, 1.22),
    (200, 558.0, 1.45),
    (300, 845.0, 2.18),
    (500, 1420.0, 2.4),
    (700, 1955.0, 2.9),
    (1000, 2798.0, 4.2),
];

/// Assumed convergence iteration count baked into the per-op rates.
/// (The paper never states its iteration count; rates below are per-pixel
/// *per run*, i.e. I is folded in during calibration.)
pub const CALIB_ITERS: f64 = 100.0;

#[derive(Clone, Debug)]
pub struct CostModel {
    pub gpu: DeviceSpec,
    pub cpu: DeviceSpec,
    /// Sequential per-pixel-per-run seconds (calibrated: their C code on
    /// the i5 averages 2.82 s/KB across Table 3 — about 1.9 effective
    /// MFLOP/s, i.e. ~0.01% of the i5's 23 GFLOPs peak; the paper's
    /// superlinear speedup is largely this baseline inefficiency).
    pub t_px_cpu: f64,
    /// CPU cache penalty multiplier once the working set spills LLC.
    pub cpu_cache_penalty: f64,
    /// Parallel asymptotic per-pixel-per-run seconds (large-n plateau of
    /// their parallel column: ~4.1e-3 s/KB).
    pub t_px_gpu: f64,
    /// Mid-size contention bump: amplitude (relative to t_px_gpu),
    /// center (bytes) and log-width. Calibrated on their parallel column.
    pub bump_amp: f64,
    pub bump_center_bytes: f64,
    pub bump_log_sigma: f64,
    /// Fixed per-run overhead on the GPU (setup + final transfers).
    pub t_fixed_gpu: f64,
    /// Ablation toggles (bench-ablation flips these).
    pub enable_bump: bool,
    pub enable_cpu_cache_term: bool,
    pub enable_transfer: bool,
    pub enable_launch_overhead: bool,
    /// Clusters (kernel launches per phase scale with c — Section 4.2).
    pub clusters: usize,
}

impl CostModel {
    /// The calibrated model of the paper's testbed.
    pub fn calibrated_c2050() -> CostModel {
        CostModel {
            gpu: TESLA_C2050,
            cpu: INTEL_I5_480,
            // Their sequential column is near-linear at 2.83 s/KB (+-4%)
            // => per pixel (KB = 1024 px).
            t_px_cpu: 2.83 / 1024.0,
            // Their data shows no LLC spill kink; keep the term as an
            // ablation knob (what a cache-bound baseline WOULD look like).
            cpu_cache_penalty: 0.0,
            // 4.15e-3 s/KB asymptote of their parallel column.
            t_px_gpu: 4.15e-3 / 1024.0,
            bump_amp: 0.80,
            bump_center_bytes: 190.0 * 1024.0,
            bump_log_sigma: 0.67,
            t_fixed_gpu: 0.018,
            enable_bump: true,
            enable_cpu_cache_term: true,
            enable_transfer: true,
            enable_launch_overhead: true,
            clusters: 4,
        }
    }

    /// Sequential FCM seconds for a dataset of `bytes` pixels.
    pub fn seq_seconds(&self, bytes: usize) -> f64 {
        let n = bytes as f64;
        // Working set: x (4B) + u,u_new (2*c*4B) per pixel.
        let ws = n * (4.0 + 8.0 * self.clusters as f64);
        let mut penalty = 1.0;
        if self.enable_cpu_cache_term {
            // Smooth LLC spill: up to +cpu_cache_penalty when ws >> LLC.
            let x = (ws / self.cpu.llc_bytes as f64).ln();
            penalty += self.cpu_cache_penalty / (1.0 + (-x).exp());
        }
        n * self.t_px_cpu * penalty
    }

    /// Parallel FCM seconds for a dataset of `bytes` pixels.
    pub fn par_seconds(&self, bytes: usize) -> f64 {
        let n = bytes as f64;
        let mut t = self.t_fixed_gpu;
        if self.enable_transfer {
            // x up once, memberships down each epsilon test (paper 4.3
            // ships u back per iteration; fold into the calibrated fixed +
            // linear terms, count the explicit initial transfer here).
            let bytes_moved = bytes as f64 * (4.0 + 4.0 * self.clusters as f64);
            t += bytes_moved / (self.gpu.pcie_gbs * 1e9);
        }
        if self.enable_launch_overhead {
            // Per run: I iterations x (4 kernels x c clusters + 1 kernel).
            let launches = CALIB_ITERS * (4.0 * self.clusters as f64 + 1.0);
            t += launches * self.gpu.launch_overhead_s;
        }
        let mut per_px = self.t_px_gpu;
        if self.enable_bump {
            let z = (bytes as f64 / self.bump_center_bytes).ln() / self.bump_log_sigma;
            per_px += self.t_px_gpu * self.bump_amp * (-0.5 * z * z).exp();
        }
        // Algorithm-2 reduction: logarithmic stage count, negligible per
        // element but kept for structure (and the reduction demo).
        let red = self.gpu.reduction_steps(bytes) as f64
            * self.gpu.launch_overhead_s
            * CALIB_ITERS
            * if self.enable_launch_overhead { 1.0 } else { 0.0 };
        t + n * per_px + red
    }

    /// Speedup (the paper's Fig. 8 series).
    pub fn speedup(&self, bytes: usize) -> f64 {
        self.seq_seconds(bytes) / self.par_seconds(bytes)
    }

    /// Whether the model calls `bytes` superlinear (speedup > processors).
    pub fn superlinear(&self, bytes: usize) -> bool {
        self.speedup(bytes) > self.gpu.processors as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_sequential_within_5pct() {
        let m = CostModel::calibrated_c2050();
        for &(kb, seq, _) in &PAPER_TABLE3 {
            let got = m.seq_seconds(kb * 1024);
            let err = (got - seq).abs() / seq;
            assert!(err < 0.05, "{kb}KB: model {got:.0}s vs paper {seq}s");
        }
    }

    #[test]
    fn matches_paper_parallel_within_25pct() {
        // The parallel column is noisier (their 30-run averages wobble);
        // the model must stay within 25% everywhere and 15% on average.
        let m = CostModel::calibrated_c2050();
        let mut errs = Vec::new();
        for &(kb, _, par) in &PAPER_TABLE3 {
            let got = m.par_seconds(kb * 1024);
            let err = (got - par).abs() / par;
            assert!(err < 0.25, "{kb}KB: model {got:.3}s vs paper {par}s");
            errs.push(err);
        }
        let mean = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(mean < 0.15, "mean error {mean:.3}");
    }

    #[test]
    fn fig8_shape_superlinear_ends_dip_middle() {
        let m = CostModel::calibrated_c2050();
        // Superlinear at both ends (paper Fig. 8).
        assert!(m.superlinear(20 * 1024), "20KB should be superlinear");
        assert!(m.superlinear(40 * 1024));
        assert!(m.superlinear(700 * 1024));
        assert!(m.superlinear(1000 * 1024));
        // Dip below 448 in the mid-range (open question #3).
        assert!(!m.superlinear(160 * 1024), "160KB should dip");
        assert!(!m.superlinear(200 * 1024));
        assert!(!m.superlinear(300 * 1024));
    }

    #[test]
    fn crossovers_near_paper_locations() {
        let m = CostModel::calibrated_c2050();
        // Lower crossover between 80 and 140 KB.
        let lower = (80..=140)
            .find(|kb| !m.superlinear(kb * 1024))
            .expect("no lower crossover");
        assert!((80..=140).contains(&lower), "lower at {lower}KB");
        // Upper crossover between 300 and 500 KB.
        let upper = (300..=500)
            .find(|kb| m.superlinear(kb * 1024))
            .expect("no upper crossover");
        assert!((300..=500).contains(&upper), "upper at {upper}KB");
    }

    #[test]
    fn headline_speedup_band() {
        // Paper: up to ~674-fold at 700 KB; our model should put 700KB-1MB
        // in the 550-700x band.
        let m = CostModel::calibrated_c2050();
        for kb in [700usize, 1000] {
            let s = m.speedup(kb * 1024);
            assert!((550.0..700.0).contains(&s), "{kb}KB speedup {s:.0}");
        }
    }

    #[test]
    fn ablation_disabling_bump_restores_monotone_region() {
        let mut m = CostModel::calibrated_c2050();
        m.enable_bump = false;
        // Without the contention bump the mid-range is superlinear too.
        assert!(m.superlinear(200 * 1024));
        assert!(m.superlinear(300 * 1024));
    }

    #[test]
    fn transfer_and_launch_terms_positive() {
        let m = CostModel::calibrated_c2050();
        let mut m2 = m.clone();
        m2.enable_transfer = false;
        m2.enable_launch_overhead = false;
        for kb in [20usize, 200, 1000] {
            assert!(m.par_seconds(kb * 1024) > m2.par_seconds(kb * 1024));
        }
    }
}
