//! Bench: paper Table 3 — execution time of sequential vs parallel FCM
//! across dataset sizes 20KB..1MB (experiment E8).
//!
//!   cargo bench --bench table3            # full 14 sizes
//!   REPRO_BENCH_QUICK=1 cargo bench ...   # 3 sizes, CI-friendly

use repro::config::Config;
use repro::report::experiments as exp;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let cfg = Config::new();
    let sizes = exp::table3_sizes(quick);
    let runs = if quick { 3 } else { 5 };
    println!("== bench table3: {} sizes, {} runs each ==", sizes.len(), runs);
    println!("(paper columns shown for reference; sim = calibrated C2050/i5");
    println!(" model of the paper's testbed; our = this stack, measured)\n");
    let t = exp::table3(&cfg, &sizes, runs)?;
    t.print();
    println!("\nmarkdown (for EXPERIMENTS.md):\n{}", t.to_markdown());
    Ok(())
}
