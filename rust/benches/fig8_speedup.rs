//! Bench: paper Fig. 8 — speedup curve with the 448-PE line, plus the
//! ablation probing the Section 5.3 open questions (experiments E9, E10).
//!
//!   cargo bench --bench fig8_speedup

use repro::gpu_sim::{CostModel, PAPER_TABLE3, TESLA_C2050};
use repro::report::experiments as exp;

fn main() {
    println!("== bench fig8: speedup vs dataset size ==\n");
    let (table, chart) = exp::fig8(&exp::fig8_sizes());
    table.print();
    println!("\n{chart}");

    // Crossover locations (the paper's superlinear/sublinear boundaries).
    let model = CostModel::calibrated_c2050();
    let mut prev: Option<(usize, bool)> = None;
    println!("crossovers of the {}-PE line:", TESLA_C2050.processors);
    for kb in (10..=1100).step_by(2) {
        let s = model.superlinear(kb * 1024);
        if let Some((pkb, ps)) = prev {
            if ps != s {
                println!(
                    "  {} -> {} between {pkb}KB and {kb}KB",
                    if ps { "superlinear" } else { "sublinear" },
                    if s { "superlinear" } else { "sublinear" },
                );
            }
        }
        prev = Some((kb, s));
    }
    println!("(paper: dips below 448x between ~100KB and ~360KB)\n");

    println!("== ablation (E10) ==\n");
    exp::ablation(&exp::table3_sizes(false)).print();

    // Model-vs-paper error summary.
    let mut errs = Vec::new();
    for &(kb, seq, par) in &PAPER_TABLE3 {
        let s = model.speedup(kb * 1024);
        let p = seq / par;
        errs.push(((s - p) / p).abs());
    }
    let mean = errs.iter().sum::<f64>() / errs.len() as f64;
    println!(
        "\nmodel-vs-paper speedup error: mean {:.1}% max {:.1}%",
        mean * 100.0,
        errs.iter().cloned().fold(0.0, f64::max) * 100.0
    );
}
