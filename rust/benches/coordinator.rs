//! Bench: L3 coordinator throughput — workers x batch-size x batched-vs-
//! looped execution sweep over a homogeneous slice workload, per serving
//! engine. Not a paper table (the paper has no serving layer); this is
//! the perf gate for DESIGN.md S12 and the §Perf log in EXPERIMENTS.md.
//!
//! The `batched` column is the tentpole A/B: `true` executes each formed
//! batch through ONE `segment_batch` engine invocation (the parallel
//! engine interleaves all images through one pool pass per iteration);
//! `false` loops `segment` per job inside the worker. Results are
//! bit-identical either way — only throughput and batch latency move.
//!
//! Engines swept: the host engines always (Parallel, Histogram); the
//! device engine only when AOT artifacts are present.
//!
//!   cargo bench --bench coordinator

use repro::config::Config;
use repro::coordinator::{Engine, Service};
use repro::fcm::FcmParams;
use repro::phantom::{generate_slice, PhantomConfig};
use repro::report::Table;

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let jobs = if quick { 8 } else { 24 };
    // Pre-generate the workload once. Same-shape slices: every job lands
    // in one shape bucket, so max_batch is the only batching limit.
    let slices: Vec<_> = (0..jobs)
        .map(|i| {
            generate_slice(&PhantomConfig {
                slice: 70 + (i * 5) % 60,
                seed: i as u64,
                ..PhantomConfig::default()
            })
        })
        .collect();
    let params = FcmParams::default();

    let mut engines = vec![Engine::Parallel, Engine::Histogram];
    if repro::runtime::device_available(std::path::Path::new("artifacts")) {
        engines.insert(0, Engine::Device);
    } else {
        println!("(device path unavailable — artifacts missing or stub xla linked; skipped)\n");
    }

    let mut t = Table::new([
        "engine",
        "workers",
        "max_batch",
        "batched",
        "wall(s)",
        "jobs/s",
        "mean wait(s)",
        "mean batch",
        "batch lat(s)",
    ]);
    for &engine in &engines {
        for workers in [1usize, 2, 4] {
            for max_batch in [1usize, 8] {
                // batch_execute only matters for multi-job batches.
                let modes: &[bool] = if max_batch > 1 { &[true, false] } else { &[true] };
                for &batch_execute in modes {
                    let mut cfg = Config::new();
                    cfg.service.workers = workers;
                    cfg.service.max_batch = max_batch;
                    cfg.service.batch_execute = batch_execute;
                    let service = Service::start(&cfg)?;
                    let t0 = std::time::Instant::now();
                    let tickets: Vec<_> = slices
                        .iter()
                        .map(|s| service.submit_image(&s.image, params, engine))
                        .collect::<anyhow::Result<_>>()?;
                    for ticket in tickets {
                        ticket.wait()?;
                    }
                    let wall = t0.elapsed().as_secs_f64();
                    let snap = service.shutdown();
                    let (batch_size, batch_lat) = snap
                        .engine_stats(engine)
                        .map(|e| (e.mean_batch_size, e.mean_batch_latency_s))
                        .unwrap_or((0.0, 0.0));
                    t.row([
                        format!("{engine:?}"),
                        workers.to_string(),
                        max_batch.to_string(),
                        batch_execute.to_string(),
                        format!("{wall:.2}"),
                        format!("{:.2}", jobs as f64 / wall),
                        format!("{:.3}", snap.mean_queue_wait_s),
                        format!("{batch_size:.2}"),
                        format!("{batch_lat:.3}"),
                    ]);
                }
            }
        }
    }
    println!("== bench coordinator: {jobs} slice jobs per engine ==\n");
    t.print();
    Ok(())
}
