//! Bench: paper Table 1 frame — all methods in this repo measured on the
//! same workload (experiment E1), plus a phase-level breakdown of the
//! sequential baseline (the paper's Section 4 dependency analysis:
//! center sums vs membership updates).
//!
//!   cargo bench --bench baselines

use repro::config::Config;
use repro::fcm::{sequential, FcmParams};
use repro::harness::{bench, Opts};
use repro::image::FeatureVector;
use repro::phantom::sized_dataset;
use repro::report::{experiments as exp, fmt_secs, Table};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let runs = if quick { 3 } else { 5 };
    let cfg = Config::new();

    println!("== bench baselines (Table 1 frame) ==\n");
    exp::table1(&cfg, runs)?.print();

    // Phase breakdown: where does the sequential time go? (The paper's
    // Section 4 argues the center-sum "sigma operations" dominate and
    // motivate the reduction kernels.)
    println!("\n== sequential phase breakdown (100KB) ==\n");
    let params = FcmParams::default();
    let data = sized_dataset(100 * 1024, 42);
    let fv = FeatureVector::from_image(&data.image);
    let n = fv.x.len();
    let c = params.clusters;
    let u = repro::fcm::init_membership(c, n, params.seed);
    let mut centers = vec![0f32; c];
    let mut u_new = vec![0f32; c * n];

    let opts = Opts {
        warmup: 1,
        min_runs: runs,
        max_runs: runs.max(10),
        max_seconds: 5.0,
    };
    let b_centers = bench("centers", &opts, || {
        sequential::update_centers(&fv.x, &fv.w, &u, c, params.m as f64, &mut centers);
    });
    let b_members = bench("memberships", &opts, || {
        let _ = sequential::update_memberships(
            &fv.x, &fv.w, &centers, params.m as f64, &u, &mut u_new,
        );
    });
    let mut t = Table::new(["phase", "per-iteration(s)", "share"]);
    let total = b_centers.mean() + b_members.mean();
    t.row([
        "centers (Eq. 3 sigma sums)",
        &fmt_secs(b_centers.mean()),
        &format!("{:.0}%", 100.0 * b_centers.mean() / total),
    ]);
    t.row([
        "memberships (Eq. 4)",
        &fmt_secs(b_members.mean()),
        &format!("{:.0}%", 100.0 * b_members.mean() / total),
    ]);
    t.print();
    Ok(())
}
