//! Bench: host-engine comparison (the PR-1 perf gate) + the Table 1
//! frame + the sequential phase breakdown.
//!
//! Measures the three host paths on the same phantom workloads:
//!   * sequential — paper Algorithm 1, the Table 3 comparator,
//!   * parallel   — fcm::engine fused iterations + chunked deterministic
//!                  tree reductions over all cores,
//!   * histogram  — the brFCM <=256-bin fast path,
//! plus the device path when AOT artifacts are present.
//!
//! Results are written to BENCH_PR1.json at the repo root (mean/p95 per
//! size, speedups vs sequential) so the numbers are tracked in-repo.
//!
//! PR 7 adds the fused-kernel A/B: the same engines with the explicit-
//! SIMD kernel forced off, then on (`fused::set_simd`), sizes as above,
//! plus a byte-identity gate across engines x thread counts — the
//! toggle is result-neutral by contract, so the sweep measures time
//! only. That section goes to BENCH_PR7.json (shared with the
//! streaming bench's u16 section).
//!
//!   cargo bench --bench baselines
//!   REPRO_BENCH_QUICK=1 cargo bench --bench baselines   # CI smoke
//!
//! Perf gate: histogram >= 8x over sequential on the 100KB phantom at
//! default params (c=4, m=2); parallel bit-identical across thread
//! counts. Both are printed as GATE lines at the end.

use repro::config::Config;
use repro::fcm::{engine, sequential, Backend, EngineOpts, FcmParams};
use repro::harness::{bench, BenchResult, Opts};
use repro::image::FeatureVector;
use repro::phantom::sized_dataset;
use repro::report::{experiments as exp, fmt_secs, fmt_x, Table};

struct SizeRow {
    bytes: usize,
    seq: BenchResult,
    par: BenchResult,
    hist: BenchResult,
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let runs = if quick { 3 } else { 5 };
    let cfg = Config::new();
    let params = FcmParams::from(&cfg.fcm);
    let threads = repro::fcm::engine::parallel::resolve_threads(cfg.engine.threads);

    println!("== bench baselines (Table 1 frame) ==\n");
    exp::table1(&cfg, runs)?.print();

    // Host-engine sweep: the 100KB phantom is the gated size; the full
    // run adds the 20KB and 300KB points of the Table 3 axis.
    let sizes: Vec<usize> = if quick {
        vec![100 * 1024]
    } else {
        vec![20 * 1024, 100 * 1024, 300 * 1024]
    };
    let opts = Opts {
        warmup: 1,
        min_runs: runs.min(3),
        max_runs: runs,
        max_seconds: 30.0,
    };

    println!("\n== host engines: sequential vs parallel vs histogram ==");
    println!("(threads = {threads}, chunk = {}; c=4, m=2, eps=0.005)\n", cfg.engine.chunk);
    let mut t = Table::new([
        "size", "seq mean", "seq p95", "par mean", "par p95", "hist mean", "hist p95",
        "par x", "hist x",
    ]);
    let mut rows = Vec::new();
    for &bytes in &sizes {
        let kb = bytes / 1024;
        let data = sized_dataset(bytes, cfg.fcm.seed);
        let fv = FeatureVector::from_image(&data.image);
        let seq = bench(&format!("seq-{kb}KB"), &opts, || {
            let _ = sequential::run(&fv.x, &fv.w, &params);
        });
        let par = bench(&format!("par-{kb}KB"), &opts, || {
            let o = EngineOpts::with_backend(Backend::Parallel);
            let _ = engine::run(&fv.x, &fv.w, &params, &o);
        });
        let hist = bench(&format!("hist-{kb}KB"), &opts, || {
            let o = EngineOpts::with_backend(Backend::Histogram);
            let _ = engine::run(&fv.x, &fv.w, &params, &o);
        });
        t.row([
            format!("{kb}KB"),
            fmt_secs(seq.mean()),
            fmt_secs(seq.seconds.p95),
            fmt_secs(par.mean()),
            fmt_secs(par.seconds.p95),
            fmt_secs(hist.mean()),
            fmt_secs(hist.seconds.p95),
            fmt_x(seq.mean() / par.mean()),
            fmt_x(seq.mean() / hist.mean()),
        ]);
        rows.push(SizeRow {
            bytes,
            seq,
            par,
            hist,
        });
    }
    t.print();

    // Phase breakdown: where does the sequential time go? (The paper's
    // Section 4 argues the center-sum "sigma operations" dominate and
    // motivate the reduction kernels.)
    println!("\n== sequential phase breakdown (100KB) ==\n");
    let data = sized_dataset(100 * 1024, 42);
    let fv = FeatureVector::from_image(&data.image);
    let n = fv.x.len();
    let c = params.clusters;
    let u = repro::fcm::init_membership(c, n, params.seed);
    let mut centers = vec![0f32; c];
    let mut u_new = vec![0f32; c * n];
    let phase_opts = Opts {
        warmup: 1,
        min_runs: runs,
        max_runs: runs.max(10),
        max_seconds: 5.0,
    };
    let b_centers = bench("centers", &phase_opts, || {
        sequential::update_centers(&fv.x, &fv.w, &u, c, params.m as f64, &mut centers);
    });
    let b_members = bench("memberships", &phase_opts, || {
        let _ = sequential::update_memberships(
            &fv.x, &fv.w, &centers, params.m as f64, &u, &mut u_new,
        );
    });
    let mut pt = Table::new(["phase", "per-iteration(s)", "share"]);
    let total = b_centers.mean() + b_members.mean();
    pt.row([
        "centers (Eq. 3 sigma sums)",
        &fmt_secs(b_centers.mean()),
        &format!("{:.0}%", 100.0 * b_centers.mean() / total),
    ]);
    pt.row([
        "memberships (Eq. 4)",
        &fmt_secs(b_members.mean()),
        &format!("{:.0}%", 100.0 * b_members.mean() / total),
    ]);
    pt.print();

    // Determinism gate: the parallel engine must be bit-identical across
    // thread counts (the Algorithm-2 fixed-order reduction contract).
    let det_data = sized_dataset(60 * 1024, 7);
    let det_fv = FeatureVector::from_image(&det_data.image);
    let u0 = repro::fcm::init_membership(c, det_fv.x.len(), 7);
    let opts1 = EngineOpts {
        backend: Backend::Parallel,
        threads: 1,
        chunk: 4096,
    };
    let opts8 = EngineOpts {
        threads: 8,
        ..opts1
    };
    let r1 = engine::run_from(&det_fv.x, &det_fv.w, u0.clone(), &params, &opts1);
    let r8 = engine::run_from(&det_fv.x, &det_fv.w, u0, &params, &opts8);
    let deterministic = r1.centers == r8.centers && r1.u == r8.u;

    // The 100KB histogram gate.
    let gate = rows
        .iter()
        .find(|r| r.bytes == 100 * 1024)
        .map(|r| r.seq.mean() / r.hist.mean())
        .unwrap_or(0.0);
    println!(
        "\nGATE histogram >= 8x @100KB: {} ({gate:.1}x)",
        if gate >= 8.0 { "PASS" } else { "FAIL" }
    );
    println!(
        "GATE parallel deterministic across thread counts: {}",
        if deterministic { "PASS" } else { "FAIL" }
    );

    // PR 7 — fused kernel A/B: scalar vs explicit-SIMD. Byte identity
    // first (engines x thread counts, shared u0 — the result-neutral
    // contract), then the timing sweep over the same sizes.
    println!(
        "\n== fused kernel: scalar vs SIMD (lane width {}) ==\n",
        engine::fused::simd_width()
    );
    let mut simd_identical = true;
    {
        let u0s = repro::fcm::init_membership(c, det_fv.x.len(), 3);
        for backend in [Backend::Parallel, Backend::Histogram] {
            for t in [1usize, 2, 8] {
                let o = EngineOpts {
                    backend,
                    threads: t,
                    chunk: 4096,
                };
                engine::fused::set_simd(false);
                let a = engine::run_from(&det_fv.x, &det_fv.w, u0s.clone(), &params, &o);
                engine::fused::set_simd(true);
                let b = engine::run_from(&det_fv.x, &det_fv.w, u0s.clone(), &params, &o);
                simd_identical &= a.u == b.u
                    && a.centers == b.centers
                    && a.labels == b.labels
                    && a.iterations == b.iterations;
            }
        }
    }
    let mut st = Table::new([
        "size", "par scalar", "par simd", "par x", "hist scalar", "hist simd", "hist x",
    ]);
    let mut simd_rows = Vec::new();
    for &bytes in &sizes {
        let kb = bytes / 1024;
        let data = sized_dataset(bytes, cfg.fcm.seed);
        let fv = FeatureVector::from_image(&data.image);
        let time = |label: &str, backend: Backend, simd: bool| {
            engine::fused::set_simd(simd);
            bench(&format!("{label}-{kb}KB"), &opts, || {
                let o = EngineOpts::with_backend(backend);
                let _ = engine::run(&fv.x, &fv.w, &params, &o);
            })
        };
        let par_scalar = time("par-scalar", Backend::Parallel, false);
        let par_simd = time("par-simd", Backend::Parallel, true);
        let hist_scalar = time("hist-scalar", Backend::Histogram, false);
        let hist_simd = time("hist-simd", Backend::Histogram, true);
        st.row([
            format!("{kb}KB"),
            fmt_secs(par_scalar.mean()),
            fmt_secs(par_simd.mean()),
            fmt_x(par_scalar.mean() / par_simd.mean()),
            fmt_secs(hist_scalar.mean()),
            fmt_secs(hist_simd.mean()),
            fmt_x(hist_scalar.mean() / hist_simd.mean()),
        ]);
        simd_rows.push((bytes, par_scalar, par_simd, hist_scalar, hist_simd));
    }
    st.print();
    println!(
        "\nGATE simd byte-identical to scalar (engines x threads): {}",
        if simd_identical { "PASS" } else { "FAIL" }
    );
    // Hand the toggle back to the env-resolved default.
    engine::fused::set_simd(match std::env::var("REPRO_SIMD") {
        Ok(v) => !(v == "0" || v.eq_ignore_ascii_case("false") || v.eq_ignore_ascii_case("off")),
        Err(_) => true,
    });

    write_json(&rows, threads, gate, deterministic, quick)?;
    write_pr7_fused(&simd_rows, simd_identical, quick)?;
    if !simd_identical {
        anyhow::bail!("simd byte-identity gate failed");
    }
    Ok(())
}

/// The scalar-vs-SIMD section of BENCH_PR7.json (shared with the
/// streaming bench's `histogram_u16` section — see
/// [`write_pr7_section`]).
fn write_pr7_fused(
    rows: &[(usize, BenchResult, BenchResult, BenchResult, BenchResult)],
    identical: bool,
    quick: bool,
) -> anyhow::Result<()> {
    let mut sizes = String::new();
    for (i, (bytes, ps, pv, hs, hv)) in rows.iter().enumerate() {
        sizes.push_str(&format!(
            "{{\"bytes\": {bytes}, \"parallel_scalar_s\": {:.6}, \"parallel_simd_s\": {:.6}, \
             \"parallel_speedup\": {:.3}, \"histogram_scalar_s\": {:.6}, \
             \"histogram_simd_s\": {:.6}, \"histogram_speedup\": {:.3}}}{}",
            ps.mean(),
            pv.mean(),
            ps.mean() / pv.mean(),
            hs.mean(),
            hv.mean(),
            hs.mean() / hv.mean(),
            if i + 1 == rows.len() { "" } else { ", " }
        ));
    }
    let section = format!(
        "{{\"status\": \"measured\", \"quick\": {quick}, \"lane_width\": {}, \
         \"gate_byte_identical\": {identical}, \"sizes\": [{sizes}]}}",
        engine::fused::simd_width()
    );
    write_pr7_section("fused_simd", section)
}

/// Rewrite BENCH_PR7.json with our section replaced and the other
/// bench's section (one line per section, by construction) carried over
/// verbatim — the two PR-7 benches share the file without serde. A twin
/// of this helper lives in benches/streaming.rs.
fn write_pr7_section(section: &str, value: String) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR7.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR7.json"),
    };
    let old = std::fs::read_to_string(&path).unwrap_or_default();
    let mut kept = Vec::new();
    for name in ["fused_simd", "histogram_u16"] {
        kept.push(if name == section {
            format!("  \"{name}\": {value}")
        } else {
            old.lines()
                .find(|l| l.trim_start().starts_with(&format!("\"{name}\":")))
                .map(|l| l.trim_end().trim_end_matches(',').to_string())
                .unwrap_or_else(|| format!("  \"{name}\": \"pending\""))
        });
    }
    let s = format!(
        "{{\n  \"pr\": 7,\n  \"bench\": \"fused-simd + histogram-u16\",\n{},\n{}\n}}\n",
        kept[0], kept[1]
    );
    std::fs::write(&path, &s)?;
    println!("wrote {} ({section})", path.display());
    Ok(())
}

/// Record the host-engine numbers in BENCH_PR1.json at the repo root
/// (hand-rolled JSON: the offline build has no serde).
fn write_json(
    rows: &[SizeRow],
    threads: usize,
    gate_hist_100kb: f64,
    deterministic: bool,
    quick: bool,
) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR1.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR1.json"),
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 1,\n");
    s.push_str("  \"bench\": \"baselines\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"params\": {\"clusters\": 4, \"m\": 2.0, \"epsilon\": 0.005, \"seed\": 42},\n");
    s.push_str(&format!("  \"engine_threads\": {threads},\n"));
    s.push_str(&format!(
        "  \"gates\": {{\"histogram_speedup_100kb\": {gate_hist_100kb:.3}, \"histogram_gate_pass\": {}, \"parallel_deterministic\": {deterministic}}},\n",
        gate_hist_100kb >= 8.0
    ));
    s.push_str("  \"sizes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let eng = |b: &BenchResult| {
            format!(
                "{{\"mean_s\": {:.6}, \"p95_s\": {:.6}, \"runs\": {}}}",
                b.mean(),
                b.seconds.p95,
                b.runs
            )
        };
        s.push_str(&format!(
            "    {{\"bytes\": {}, \"sequential\": {}, \"parallel\": {}, \"histogram\": {}, \"speedup_parallel\": {:.3}, \"speedup_histogram\": {:.3}}}{}\n",
            r.bytes,
            eng(&r.seq),
            eng(&r.par),
            eng(&r.hist),
            r.seq.mean() / r.par.mean(),
            r.seq.mean() / r.hist.mean(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, &s)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
