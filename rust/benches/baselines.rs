//! Bench: host-engine comparison (the PR-1 perf gate) + the Table 1
//! frame + the sequential phase breakdown.
//!
//! Measures the three host paths on the same phantom workloads:
//!   * sequential — paper Algorithm 1, the Table 3 comparator,
//!   * parallel   — fcm::engine fused iterations + chunked deterministic
//!                  tree reductions over all cores,
//!   * histogram  — the brFCM <=256-bin fast path,
//! plus the device path when AOT artifacts are present.
//!
//! Results are written to BENCH_PR1.json at the repo root (mean/p95 per
//! size, speedups vs sequential) so the numbers are tracked in-repo.
//!
//!   cargo bench --bench baselines
//!   REPRO_BENCH_QUICK=1 cargo bench --bench baselines   # CI smoke
//!
//! Perf gate: histogram >= 8x over sequential on the 100KB phantom at
//! default params (c=4, m=2); parallel bit-identical across thread
//! counts. Both are printed as GATE lines at the end.

use repro::config::Config;
use repro::fcm::{engine, sequential, Backend, EngineOpts, FcmParams};
use repro::harness::{bench, BenchResult, Opts};
use repro::image::FeatureVector;
use repro::phantom::sized_dataset;
use repro::report::{experiments as exp, fmt_secs, fmt_x, Table};

struct SizeRow {
    bytes: usize,
    seq: BenchResult,
    par: BenchResult,
    hist: BenchResult,
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let runs = if quick { 3 } else { 5 };
    let cfg = Config::new();
    let params = FcmParams::from(&cfg.fcm);
    let threads = repro::fcm::engine::parallel::resolve_threads(cfg.engine.threads);

    println!("== bench baselines (Table 1 frame) ==\n");
    exp::table1(&cfg, runs)?.print();

    // Host-engine sweep: the 100KB phantom is the gated size; the full
    // run adds the 20KB and 300KB points of the Table 3 axis.
    let sizes: Vec<usize> = if quick {
        vec![100 * 1024]
    } else {
        vec![20 * 1024, 100 * 1024, 300 * 1024]
    };
    let opts = Opts {
        warmup: 1,
        min_runs: runs.min(3),
        max_runs: runs,
        max_seconds: 30.0,
    };

    println!("\n== host engines: sequential vs parallel vs histogram ==");
    println!("(threads = {threads}, chunk = {}; c=4, m=2, eps=0.005)\n", cfg.engine.chunk);
    let mut t = Table::new([
        "size", "seq mean", "seq p95", "par mean", "par p95", "hist mean", "hist p95",
        "par x", "hist x",
    ]);
    let mut rows = Vec::new();
    for &bytes in &sizes {
        let kb = bytes / 1024;
        let data = sized_dataset(bytes, cfg.fcm.seed);
        let fv = FeatureVector::from_image(&data.image);
        let seq = bench(&format!("seq-{kb}KB"), &opts, || {
            let _ = sequential::run(&fv.x, &fv.w, &params);
        });
        let par = bench(&format!("par-{kb}KB"), &opts, || {
            let o = EngineOpts::with_backend(Backend::Parallel);
            let _ = engine::run(&fv.x, &fv.w, &params, &o);
        });
        let hist = bench(&format!("hist-{kb}KB"), &opts, || {
            let o = EngineOpts::with_backend(Backend::Histogram);
            let _ = engine::run(&fv.x, &fv.w, &params, &o);
        });
        t.row([
            format!("{kb}KB"),
            fmt_secs(seq.mean()),
            fmt_secs(seq.seconds.p95),
            fmt_secs(par.mean()),
            fmt_secs(par.seconds.p95),
            fmt_secs(hist.mean()),
            fmt_secs(hist.seconds.p95),
            fmt_x(seq.mean() / par.mean()),
            fmt_x(seq.mean() / hist.mean()),
        ]);
        rows.push(SizeRow {
            bytes,
            seq,
            par,
            hist,
        });
    }
    t.print();

    // Phase breakdown: where does the sequential time go? (The paper's
    // Section 4 argues the center-sum "sigma operations" dominate and
    // motivate the reduction kernels.)
    println!("\n== sequential phase breakdown (100KB) ==\n");
    let data = sized_dataset(100 * 1024, 42);
    let fv = FeatureVector::from_image(&data.image);
    let n = fv.x.len();
    let c = params.clusters;
    let u = repro::fcm::init_membership(c, n, params.seed);
    let mut centers = vec![0f32; c];
    let mut u_new = vec![0f32; c * n];
    let phase_opts = Opts {
        warmup: 1,
        min_runs: runs,
        max_runs: runs.max(10),
        max_seconds: 5.0,
    };
    let b_centers = bench("centers", &phase_opts, || {
        sequential::update_centers(&fv.x, &fv.w, &u, c, params.m as f64, &mut centers);
    });
    let b_members = bench("memberships", &phase_opts, || {
        let _ = sequential::update_memberships(
            &fv.x, &fv.w, &centers, params.m as f64, &u, &mut u_new,
        );
    });
    let mut pt = Table::new(["phase", "per-iteration(s)", "share"]);
    let total = b_centers.mean() + b_members.mean();
    pt.row([
        "centers (Eq. 3 sigma sums)",
        &fmt_secs(b_centers.mean()),
        &format!("{:.0}%", 100.0 * b_centers.mean() / total),
    ]);
    pt.row([
        "memberships (Eq. 4)",
        &fmt_secs(b_members.mean()),
        &format!("{:.0}%", 100.0 * b_members.mean() / total),
    ]);
    pt.print();

    // Determinism gate: the parallel engine must be bit-identical across
    // thread counts (the Algorithm-2 fixed-order reduction contract).
    let det_data = sized_dataset(60 * 1024, 7);
    let det_fv = FeatureVector::from_image(&det_data.image);
    let u0 = repro::fcm::init_membership(c, det_fv.x.len(), 7);
    let opts1 = EngineOpts {
        backend: Backend::Parallel,
        threads: 1,
        chunk: 4096,
    };
    let opts8 = EngineOpts {
        threads: 8,
        ..opts1
    };
    let r1 = engine::run_from(&det_fv.x, &det_fv.w, u0.clone(), &params, &opts1);
    let r8 = engine::run_from(&det_fv.x, &det_fv.w, u0, &params, &opts8);
    let deterministic = r1.centers == r8.centers && r1.u == r8.u;

    // The 100KB histogram gate.
    let gate = rows
        .iter()
        .find(|r| r.bytes == 100 * 1024)
        .map(|r| r.seq.mean() / r.hist.mean())
        .unwrap_or(0.0);
    println!(
        "\nGATE histogram >= 8x @100KB: {} ({gate:.1}x)",
        if gate >= 8.0 { "PASS" } else { "FAIL" }
    );
    println!(
        "GATE parallel deterministic across thread counts: {}",
        if deterministic { "PASS" } else { "FAIL" }
    );

    write_json(&rows, threads, gate, deterministic, quick)?;
    Ok(())
}

/// Record the host-engine numbers in BENCH_PR1.json at the repo root
/// (hand-rolled JSON: the offline build has no serde).
fn write_json(
    rows: &[SizeRow],
    threads: usize,
    gate_hist_100kb: f64,
    deterministic: bool,
    quick: bool,
) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR1.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR1.json"),
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 1,\n");
    s.push_str("  \"bench\": \"baselines\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"params\": {\"clusters\": 4, \"m\": 2.0, \"epsilon\": 0.005, \"seed\": 42},\n");
    s.push_str(&format!("  \"engine_threads\": {threads},\n"));
    s.push_str(&format!(
        "  \"gates\": {{\"histogram_speedup_100kb\": {gate_hist_100kb:.3}, \"histogram_gate_pass\": {}, \"parallel_deterministic\": {deterministic}}},\n",
        gate_hist_100kb >= 8.0
    ));
    s.push_str("  \"sizes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let eng = |b: &BenchResult| {
            format!(
                "{{\"mean_s\": {:.6}, \"p95_s\": {:.6}, \"runs\": {}}}",
                b.mean(),
                b.seconds.p95,
                b.runs
            )
        };
        s.push_str(&format!(
            "    {{\"bytes\": {}, \"sequential\": {}, \"parallel\": {}, \"histogram\": {}, \"speedup_parallel\": {:.3}, \"speedup_histogram\": {:.3}}}{}\n",
            r.bytes,
            eng(&r.seq),
            eng(&r.par),
            eng(&r.hist),
            r.seq.mean() / r.par.mean(),
            r.seq.mean() / r.hist.mean(),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, &s)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
