//! Bench: volumetric FCM — the PR-3 size sweep.
//!
//! Sweeps volume sizes (slices x resolution) over the three host volume
//! paths:
//!   * slice-loop — one independent 2-D parallel-engine run per axial
//!     slice (the pre-PR-3 workflow);
//!   * slab      — the true-3D slab-decomposed engine (one fused pass
//!     over the whole volume per iteration);
//!   * hist3d    — the 3-D histogram path: one 256-bin volume histogram,
//!     per-iteration cost independent of voxel count.
//!
//! Results (mean/p95, per-voxel throughput, per-iteration time) go to
//! BENCH_PR3.json at the repo root.
//!
//!   cargo bench --bench volume
//!   REPRO_BENCH_QUICK=1 cargo bench --bench volume   # CI smoke
//!
//! Gates:
//!   * hist3d `work_per_iter` == 256 at EVERY size (the voxel-count-
//!     independence claim, asserted on the engine's work counter);
//!   * slab results bit-identical across thread counts.

use repro::fcm::engine::volume::{run_volume, VolumeOpts, BINS};
use repro::fcm::{engine, Backend, EngineOpts, FcmParams};
use repro::harness::{bench, BenchResult, Opts};
use repro::image::{FeatureVector, VoxelVolume};
use repro::phantom::{generate_volume, PhantomConfig};
use repro::report::{fmt_secs, Table};

struct SizeRow {
    width: usize,
    height: usize,
    depth: usize,
    voxels: usize,
    slice_loop: BenchResult,
    slab: BenchResult,
    hist: BenchResult,
    slab_iters: usize,
    hist_iters: usize,
    hist_work_per_iter: usize,
}

fn make_volume(width: usize, height: usize, depth: usize) -> VoxelVolume {
    generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        80,
        80 + depth,
        1,
    )
    .to_voxel_volume()
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let params = FcmParams::default();
    let sizes: Vec<(usize, usize, usize)> = if quick {
        vec![(91, 109, 10)]
    } else {
        vec![(91, 109, 10), (181, 217, 10), (181, 217, 30)]
    };
    let opts = Opts {
        warmup: 1,
        min_runs: 3,
        max_runs: if quick { 3 } else { 5 },
        max_seconds: 60.0,
    };

    println!("== volume paths: slice-loop vs slab-parallel vs 3-D histogram ==\n");
    let mut t = Table::new([
        "volume", "voxels", "loop mean", "slab mean", "hist mean", "slab x", "hist x",
        "hist s/iter",
    ]);
    let mut rows = Vec::new();
    for &(w, h, d) in &sizes {
        let vol = make_volume(w, h, d);
        let name = format!("{w}x{h}x{d}");

        // Path metadata from one untimed run each.
        let slab_run = run_volume(&vol, &params, &VolumeOpts::with_backend(Backend::Parallel));
        let hist_run = run_volume(&vol, &params, &VolumeOpts::with_backend(Backend::Histogram));

        let slice_loop = bench(&format!("loop-{name}"), &opts, || {
            let o = EngineOpts::with_backend(Backend::Parallel);
            for z in 0..vol.depth {
                let fv = FeatureVector::from_image(&vol.slice(z));
                let _ = engine::run(&fv.x, &fv.w, &params, &o);
            }
        });
        let slab = bench(&format!("slab-{name}"), &opts, || {
            let _ = run_volume(&vol, &params, &VolumeOpts::with_backend(Backend::Parallel));
        });
        let hist = bench(&format!("hist-{name}"), &opts, || {
            let _ = run_volume(&vol, &params, &VolumeOpts::with_backend(Backend::Histogram));
        });

        t.row([
            name,
            vol.len().to_string(),
            fmt_secs(slice_loop.mean()),
            fmt_secs(slab.mean()),
            fmt_secs(hist.mean()),
            format!("{:.2}x", slice_loop.mean() / slab.mean()),
            format!("{:.2}x", slice_loop.mean() / hist.mean()),
            fmt_secs(hist.mean() / hist_run.run.iterations.max(1) as f64),
        ]);
        rows.push(SizeRow {
            width: w,
            height: h,
            depth: d,
            voxels: vol.len(),
            slice_loop,
            slab,
            hist,
            slab_iters: slab_run.run.iterations,
            hist_iters: hist_run.run.iterations,
            hist_work_per_iter: hist_run.work_per_iter,
        });
    }
    t.print();

    // Gate 1: the histogram path's per-iteration work is 256 bins at
    // every size — by counter, not by clock.
    let work_gate = rows.iter().all(|r| r.hist_work_per_iter == BINS);
    println!(
        "\nGATE hist3d work/iter == {BINS} at every size: {}",
        if work_gate { "PASS" } else { "FAIL" }
    );
    // Informational: per-iteration wall time across the sweep (should
    // stay near-flat while voxel counts grow ~8x; timing, so not a hard
    // gate on shared runners).
    if rows.len() > 1 {
        let per_iter = |r: &SizeRow| r.hist.mean() / r.hist_iters.max(1) as f64;
        let lo = per_iter(&rows[0]);
        let hi = per_iter(rows.last().unwrap());
        let vox_growth = rows.last().unwrap().voxels as f64 / rows[0].voxels as f64;
        println!(
            "      hist3d s/iter {:.2e} -> {:.2e} ({:.1}x) while voxels grew {vox_growth:.1}x",
            lo,
            hi,
            hi / lo
        );
    }

    // Gate 2: slab path bit-identical across thread counts.
    let det_vol = make_volume(61, 73, 6);
    let r1 = run_volume(
        &det_vol,
        &params,
        &VolumeOpts {
            backend: Backend::Parallel,
            threads: 1,
            slab_slices: 2,
        },
    );
    let r8 = run_volume(
        &det_vol,
        &params,
        &VolumeOpts {
            backend: Backend::Parallel,
            threads: 8,
            slab_slices: 2,
        },
    );
    let deterministic = r1.run.centers == r8.run.centers && r1.run.u == r8.run.u;
    println!(
        "GATE slab path deterministic across thread counts: {}",
        if deterministic { "PASS" } else { "FAIL" }
    );

    write_json(&rows, work_gate, deterministic, quick)?;
    Ok(())
}

/// Record the sweep in BENCH_PR3.json at the repo root (hand-rolled
/// JSON: the offline build has no serde).
fn write_json(rows: &[SizeRow], work_gate: bool, deterministic: bool, quick: bool) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR3.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR3.json"),
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 3,\n");
    s.push_str("  \"bench\": \"volume\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"params\": {\"clusters\": 4, \"m\": 2.0, \"epsilon\": 0.005, \"seed\": 42},\n");
    s.push_str(&format!(
        "  \"gates\": {{\"hist3d_work_per_iter_256\": {work_gate}, \"slab_deterministic\": {deterministic}}},\n"
    ));
    s.push_str("  \"sizes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let path_json = |b: &BenchResult, iters: usize| {
            format!(
                "{{\"mean_s\": {:.6}, \"p95_s\": {:.6}, \"runs\": {}, \"mvox_per_s\": {:.3}, \"iters\": {iters}}}",
                b.mean(),
                b.seconds.p95,
                b.runs,
                r.voxels as f64 / b.mean() / 1e6
            )
        };
        s.push_str(&format!(
            "    {{\"shape\": [{}, {}, {}], \"voxels\": {}, \"slice_loop\": {}, \"slab\": {}, \"hist3d\": {}}}{}\n",
            r.width,
            r.height,
            r.depth,
            r.voxels,
            path_json(&r.slice_loop, 0),
            path_json(&r.slab, r.slab_iters),
            path_json(&r.hist, r.hist_iters),
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, &s)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
