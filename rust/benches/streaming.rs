//! Bench: out-of-core streaming execution — the PR-4 size sweep.
//!
//! Sweeps volume sizes over three ways of serving an RVOL file:
//!   * mem-hist    — materialize the file, run the in-memory 3-D
//!     histogram engine (the pre-PR-4 workflow);
//!   * stream-hist — the truly out-of-core histogram path: two
//!     streaming sweeps + bin-level iterations, resident memory
//!     bounded by the tile;
//!   * stream-slab — the tile-recompute slab path (re-reads the file
//!     once per iteration; the price of out-of-core voxel-level FCM).
//!
//! Results (mean/p95, per-voxel throughput, peak resident bytes) go to
//! BENCH_PR4.json at the repo root.
//!
//!   cargo bench --bench streaming
//!   REPRO_BENCH_QUICK=1 cargo bench --bench streaming   # CI smoke
//!
//! Gates (on counters and bytes, not clocks):
//!   * streamed labels byte-identical to the in-memory path at EVERY
//!     size, for both streamed engines;
//!   * stream-hist peak resident bytes identical across depths at a
//!     fixed tile (bounded by the tile, not the volume).

use repro::fcm::engine::stream::{run_streamed, StreamOpts, StreamRun};
use repro::fcm::engine::volume::{run_volume, VolumeOpts};
use repro::fcm::{canonical_relabel, Backend, FcmParams};
use repro::harness::{bench, BenchResult, Opts};
use repro::image::volume::stream::RvolReader;
use repro::image::{volume, VoxelVolume};
use repro::phantom::{generate_volume, PhantomConfig};
use repro::report::{fmt_secs, Table};
use std::path::{Path, PathBuf};

struct SizeRow {
    width: usize,
    height: usize,
    depth: usize,
    voxels: usize,
    mem_hist: BenchResult,
    stream_hist: BenchResult,
    stream_slab: BenchResult,
    hist_peak_bytes: usize,
    slab_peak_bytes: usize,
    identical: bool,
}

fn make_rvol(dir: &Path, width: usize, height: usize, depth: usize) -> (PathBuf, VoxelVolume) {
    let start = 90usize.min(181 - depth);
    let vol = generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
    .to_voxel_volume();
    let path = dir.join(format!("bench_{width}x{height}x{depth}.rvol"));
    volume::save_raw(&vol, &path).unwrap();
    (path, vol)
}

fn stream_once(
    path: &Path,
    params: &FcmParams,
    backend: Backend,
    tile: usize,
) -> (Vec<u8>, StreamRun) {
    let mut src = RvolReader::open(path).unwrap();
    let mut sink = Vec::new();
    let run = run_streamed(
        &mut src,
        &mut sink,
        params,
        &StreamOpts {
            backend,
            threads: 0,
            tile_slices: tile,
        },
    )
    .unwrap();
    (sink, run)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let params = FcmParams::default();
    let tile = 4usize;
    let sizes: Vec<(usize, usize, usize)> = if quick {
        vec![(91, 109, 10)]
    } else {
        vec![(91, 109, 10), (181, 217, 10), (181, 217, 40)]
    };
    let opts = Opts {
        warmup: 1,
        min_runs: 3,
        max_runs: if quick { 3 } else { 5 },
        max_seconds: 60.0,
    };
    let dir = std::env::temp_dir().join(format!("stream_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    println!("== out-of-core streaming: materialize+hist vs stream-hist vs stream-slab ==\n");
    let mut t = Table::new([
        "volume",
        "voxels",
        "mem-hist",
        "stream-hist",
        "stream-slab",
        "hist peak KB",
        "slab peak KB",
        "identical",
    ]);
    let mut rows = Vec::new();
    for &(w, h, d) in &sizes {
        let (path, vol) = make_rvol(&dir, w, h, d);
        let name = format!("{w}x{h}x{d}");

        // Equivalence + metadata from one untimed run each.
        let mut mem = run_volume(&vol, &params, &VolumeOpts::with_backend(Backend::Histogram));
        canonical_relabel(&mut mem.run);
        let (hist_labels, hist_run) = stream_once(&path, &params, Backend::Histogram, tile);
        let (slab_labels, slab_run) = stream_once(&path, &params, Backend::Parallel, tile);
        let mut mem_slab = run_volume(&vol, &params, &VolumeOpts::default());
        canonical_relabel(&mut mem_slab.run);
        let identical =
            hist_labels == mem.run.labels && slab_labels == mem_slab.run.labels;

        let mem_hist = bench(&format!("mem-hist-{name}"), &opts, || {
            let v = volume::load_raw(&path).unwrap();
            let _ = run_volume(&v, &params, &VolumeOpts::with_backend(Backend::Histogram));
        });
        let stream_hist = bench(&format!("stream-hist-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Histogram, tile);
        });
        let stream_slab = bench(&format!("stream-slab-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Parallel, tile);
        });

        t.row([
            name,
            vol.len().to_string(),
            fmt_secs(mem_hist.mean()),
            fmt_secs(stream_hist.mean()),
            fmt_secs(stream_slab.mean()),
            (hist_run.peak_resident_bytes / 1024).to_string(),
            (slab_run.peak_resident_bytes / 1024).to_string(),
            identical.to_string(),
        ]);
        rows.push(SizeRow {
            width: w,
            height: h,
            depth: d,
            voxels: vol.len(),
            mem_hist,
            stream_hist,
            stream_slab,
            hist_peak_bytes: hist_run.peak_resident_bytes,
            slab_peak_bytes: slab_run.peak_resident_bytes,
            identical,
        });
    }
    t.print();

    // Gate 1: byte identity at every size.
    let identical = rows.iter().all(|r| r.identical);
    println!(
        "\nGATE streamed output byte-identical to in-memory at every size: {}",
        if identical { "PASS" } else { "FAIL" }
    );

    // Gate 2: stream-hist peak resident bytes independent of depth at a
    // fixed tile and resolution (the out-of-core claim, on a counter).
    let peak_at = |depth: usize| {
        let (path, _) = make_rvol(&dir, 91, 109, depth);
        stream_once(&path, &params, Backend::Histogram, 2).1.peak_resident_bytes
    };
    let (p_a, p_b) = (peak_at(6), peak_at(48));
    let bounded = p_a == p_b;
    println!(
        "GATE stream-hist peak resident bytes depth-independent: {} ({p_a} vs {p_b})",
        if bounded { "PASS" } else { "FAIL" }
    );

    write_json(&rows, identical, bounded, quick)?;
    std::fs::remove_dir_all(&dir).ok();
    if !(identical && bounded) {
        anyhow::bail!("streaming gates failed");
    }
    Ok(())
}

/// Record the sweep in BENCH_PR4.json at the repo root (hand-rolled
/// JSON: the offline build has no serde).
fn write_json(rows: &[SizeRow], identical: bool, bounded: bool, quick: bool) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR4.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR4.json"),
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 4,\n");
    s.push_str("  \"bench\": \"streaming\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str("  \"params\": {\"clusters\": 4, \"m\": 2.0, \"epsilon\": 0.005, \"seed\": 42, \"tile_slices\": 4},\n");
    s.push_str(&format!(
        "  \"gates\": {{\"byte_identical\": {identical}, \"peak_depth_independent\": {bounded}}},\n"
    ));
    s.push_str("  \"sizes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let path_json = |b: &BenchResult| {
            format!(
                "{{\"mean_s\": {:.6}, \"p95_s\": {:.6}, \"runs\": {}, \"mvox_per_s\": {:.3}}}",
                b.mean(),
                b.seconds.p95,
                b.runs,
                r.voxels as f64 / b.mean() / 1e6
            )
        };
        s.push_str(&format!(
            "    {{\"shape\": [{}, {}, {}], \"voxels\": {}, \"mem_hist\": {}, \"stream_hist\": {}, \
             \"stream_slab\": {}, \"hist_peak_bytes\": {}, \"slab_peak_bytes\": {}}}{}\n",
            r.width,
            r.height,
            r.depth,
            r.voxels,
            path_json(&r.mem_hist),
            path_json(&r.stream_hist),
            path_json(&r.stream_slab),
            r.hist_peak_bytes,
            r.slab_peak_bytes,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, &s)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
