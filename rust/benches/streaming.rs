//! Bench: out-of-core streaming execution — the PR-4 size sweep,
//! extended in PR 5 with the halo-streamed spatial path and the
//! double-buffered tile prefetcher.
//!
//! Sweeps volume sizes over the ways of serving an RVOL file:
//!   * mem-hist        — materialize the file, run the in-memory 3-D
//!     histogram engine (the pre-PR-4 workflow);
//!   * stream-hist     — the truly out-of-core histogram path: two
//!     streaming sweeps + bin-level iterations, resident memory
//!     bounded by the tile;
//!   * stream-slab     — the tile-recompute slab path (re-reads the
//!     file once per iteration; the price of out-of-core voxel-level
//!     FCM);
//!   * stream-spatial  — the halo-streamed spatial path (±1-slice halo
//!     per tile, two re-reads per phase-2 iteration);
//!   * *-pf            — the same streamed paths with a TilePrefetcher
//!     reading tile k+1 while tile k computes (identical output by
//!     construction; the delta is pure I/O overlap).
//!
//! Results (mean/p95, per-voxel throughput, peak resident bytes,
//! prefetch on/off) go to BENCH_PR5.json at the repo root.
//!
//! PR 7 adds the 16-bit raster sweep: stream-hist on a genuinely wide
//! volume runs the 65 536-bin axis (per-iteration work a constant,
//! independent of voxel count — the brFCM scaling argument at 16 bits)
//! against stream-slab's per-voxel work. That section goes to
//! BENCH_PR7.json (shared with the baselines bench's SIMD section).
//!
//!   cargo bench --bench streaming
//!   REPRO_BENCH_QUICK=1 cargo bench --bench streaming   # CI smoke
//!
//! Gates (on counters and bytes, not clocks):
//!   * streamed labels byte-identical to the in-memory path at EVERY
//!     size, for all three streamed engines, prefetch on AND off;
//!   * stream-hist and stream-spatial peak resident bytes identical
//!     across depths at a fixed tile (bounded by the tile — spatial's
//!     halo adds at most 2 slices — never by the volume).

use repro::fcm::engine::stream::{
    run_streamed, run_streamed_spatial, StreamOpts, StreamRun,
};
use repro::fcm::engine::volume::{run_volume, VolumeOpts};
use repro::fcm::spatial::SpatialParams;
use repro::fcm::{canonical_relabel, spatial, Backend, FcmParams};
use repro::harness::{bench, BenchResult, Opts};
use repro::image::volume::stream::{RvolReader, TilePrefetcher, VoxelSource};
use repro::image::{volume, VoxelVolume};
use repro::phantom::{generate_volume, PhantomConfig};
use repro::report::{fmt_secs, Table};
use std::path::{Path, PathBuf};

struct SizeRow {
    width: usize,
    height: usize,
    depth: usize,
    voxels: usize,
    mem_hist: BenchResult,
    stream_hist: BenchResult,
    stream_hist_pf: BenchResult,
    stream_slab: BenchResult,
    stream_slab_pf: BenchResult,
    stream_spatial: BenchResult,
    stream_spatial_pf: BenchResult,
    hist_peak_bytes: usize,
    slab_peak_bytes: usize,
    spatial_peak_bytes: usize,
    identical: bool,
}

fn make_rvol(dir: &Path, width: usize, height: usize, depth: usize) -> (PathBuf, VoxelVolume) {
    let start = 90usize.min(181 - depth);
    let vol = generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
    .to_voxel_volume();
    let path = dir.join(format!("bench_{width}x{height}x{depth}.rvol"));
    volume::save_raw(&vol, &path).unwrap();
    (path, vol)
}

fn open(path: &Path, prefetch: bool) -> Box<dyn VoxelSource + Send> {
    let src = RvolReader::open(path).unwrap();
    if prefetch {
        Box::new(TilePrefetcher::wrap(src))
    } else {
        Box::new(src)
    }
}

fn stream_once(
    path: &Path,
    params: &FcmParams,
    backend: Backend,
    tile: usize,
    prefetch: bool,
) -> (Vec<u8>, StreamRun) {
    let mut src = open(path, prefetch);
    let mut sink = Vec::new();
    let run = run_streamed(
        &mut *src,
        &mut sink,
        params,
        &StreamOpts {
            backend,
            threads: 0,
            tile_slices: tile,
        },
    )
    .unwrap();
    (sink, run)
}

fn stream_spatial_once(
    path: &Path,
    params: &FcmParams,
    tile: usize,
    prefetch: bool,
) -> (Vec<u8>, StreamRun) {
    let mut src = open(path, prefetch);
    let mut sink = Vec::new();
    let run = run_streamed_spatial(
        &mut *src,
        &mut sink,
        params,
        &SpatialParams::default(),
        &StreamOpts {
            backend: Backend::Parallel,
            threads: 0,
            tile_slices: tile,
        },
    )
    .unwrap();
    (sink, run)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let params = FcmParams::default();
    let tile = 4usize;
    let sizes: Vec<(usize, usize, usize)> = if quick {
        vec![(91, 109, 10)]
    } else {
        vec![(91, 109, 10), (181, 217, 10), (181, 217, 40)]
    };
    let opts = Opts {
        warmup: 1,
        min_runs: 3,
        max_runs: if quick { 3 } else { 5 },
        max_seconds: 60.0,
    };
    let dir = std::env::temp_dir().join(format!("stream_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    println!("== out-of-core streaming: mem-hist vs stream-{{hist,slab,spatial}} x prefetch ==\n");
    let mut t = Table::new([
        "volume",
        "voxels",
        "mem-hist",
        "s-hist",
        "s-hist-pf",
        "s-slab",
        "s-slab-pf",
        "s-spatial",
        "s-spatial-pf",
        "hist KB",
        "slab KB",
        "spatial KB",
        "identical",
    ]);
    let mut rows = Vec::new();
    for &(w, h, d) in &sizes {
        let (path, vol) = make_rvol(&dir, w, h, d);
        let name = format!("{w}x{h}x{d}");

        // Equivalence + metadata from untimed runs: every streamed
        // engine, prefetch on AND off, against its in-memory twin.
        let mut mem = run_volume(&vol, &params, &VolumeOpts::with_backend(Backend::Histogram));
        canonical_relabel(&mut mem.run);
        let mut mem_slab = run_volume(&vol, &params, &VolumeOpts::default());
        canonical_relabel(&mut mem_slab.run);
        let mut mem_spatial = spatial::run_volume(
            &vol,
            &params,
            &SpatialParams::default(),
            &VolumeOpts::default(),
        );
        canonical_relabel(&mut mem_spatial.run);
        let (hist_labels, hist_run) = stream_once(&path, &params, Backend::Histogram, tile, false);
        let (hist_pf, _) = stream_once(&path, &params, Backend::Histogram, tile, true);
        let (slab_labels, slab_run) = stream_once(&path, &params, Backend::Parallel, tile, false);
        let (slab_pf, _) = stream_once(&path, &params, Backend::Parallel, tile, true);
        let (spatial_labels, spatial_run) = stream_spatial_once(&path, &params, tile, false);
        let (spatial_pf, _) = stream_spatial_once(&path, &params, tile, true);
        let identical = hist_labels == mem.run.labels
            && hist_pf == mem.run.labels
            && slab_labels == mem_slab.run.labels
            && slab_pf == mem_slab.run.labels
            && spatial_labels == mem_spatial.run.labels
            && spatial_pf == mem_spatial.run.labels;

        let mem_hist = bench(&format!("mem-hist-{name}"), &opts, || {
            let v = volume::load_raw(&path).unwrap();
            let _ = run_volume(&v, &params, &VolumeOpts::with_backend(Backend::Histogram));
        });
        let stream_hist = bench(&format!("stream-hist-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Histogram, tile, false);
        });
        let stream_hist_pf = bench(&format!("stream-hist-pf-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Histogram, tile, true);
        });
        let stream_slab = bench(&format!("stream-slab-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Parallel, tile, false);
        });
        let stream_slab_pf = bench(&format!("stream-slab-pf-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Parallel, tile, true);
        });
        let stream_spatial = bench(&format!("stream-spatial-{name}"), &opts, || {
            let _ = stream_spatial_once(&path, &params, tile, false);
        });
        let stream_spatial_pf = bench(&format!("stream-spatial-pf-{name}"), &opts, || {
            let _ = stream_spatial_once(&path, &params, tile, true);
        });

        t.row([
            name,
            vol.len().to_string(),
            fmt_secs(mem_hist.mean()),
            fmt_secs(stream_hist.mean()),
            fmt_secs(stream_hist_pf.mean()),
            fmt_secs(stream_slab.mean()),
            fmt_secs(stream_slab_pf.mean()),
            fmt_secs(stream_spatial.mean()),
            fmt_secs(stream_spatial_pf.mean()),
            (hist_run.peak_resident_bytes / 1024).to_string(),
            (slab_run.peak_resident_bytes / 1024).to_string(),
            (spatial_run.peak_resident_bytes / 1024).to_string(),
            identical.to_string(),
        ]);
        rows.push(SizeRow {
            width: w,
            height: h,
            depth: d,
            voxels: vol.len(),
            mem_hist,
            stream_hist,
            stream_hist_pf,
            stream_slab,
            stream_slab_pf,
            stream_spatial,
            stream_spatial_pf,
            hist_peak_bytes: hist_run.peak_resident_bytes,
            slab_peak_bytes: slab_run.peak_resident_bytes,
            spatial_peak_bytes: spatial_run.peak_resident_bytes,
            identical,
        });
    }
    t.print();

    // Gate 1: byte identity at every size, all engines, prefetch on/off.
    let identical = rows.iter().all(|r| r.identical);
    println!(
        "\nGATE streamed output byte-identical to in-memory at every size: {}",
        if identical { "PASS" } else { "FAIL" }
    );

    // Gate 2: stream-hist AND stream-spatial peak resident bytes
    // independent of depth at a fixed tile and resolution (the
    // out-of-core claim, on a counter; spatial's halo adds slices to
    // the tile, never depth-dependence).
    let peaks_at = |depth: usize| {
        let (path, _) = make_rvol(&dir, 91, 109, depth);
        let hist = stream_once(&path, &params, Backend::Histogram, 2, false).1;
        let spat = stream_spatial_once(&path, &params, 2, false).1;
        (hist.peak_resident_bytes, spat.peak_resident_bytes)
    };
    let (h_a, s_a) = peaks_at(6);
    let (h_b, s_b) = peaks_at(48);
    let bounded = h_a == h_b && s_a == s_b;
    println!(
        "GATE streamed peak resident bytes depth-independent: {} \
         (hist {h_a} vs {h_b}, spatial {s_a} vs {s_b})",
        if bounded { "PASS" } else { "FAIL" }
    );

    // PR 7 — the 16-bit raster: the 65 536-bin histogram path vs the
    // slab path on genuinely wide volumes (the 8-bit phantom spread
    // over the full u16 range with per-voxel jitter, thousands of
    // occupied levels). The gate is on the work counter: bins for the
    // histogram path at EVERY size, voxels for the slab path.
    println!("\n== 16-bit raster: stream-hist (65 536 bins) vs stream-slab ==\n");
    let sizes16: Vec<(usize, usize, usize)> = if quick {
        vec![(91, 109, 6), (91, 109, 18)]
    } else {
        vec![(91, 109, 6), (91, 109, 18), (181, 217, 24)]
    };
    let mut t16 = Table::new([
        "volume", "voxels", "s-hist16", "s-slab16", "hist work", "slab work", "hist KB",
        "slab KB", "agree",
    ]);
    let mut rows16 = Vec::new();
    for &(w, h, d) in &sizes16 {
        let path = make_rvol16(&dir, w, h, d);
        let name = format!("{w}x{h}x{d}");
        let (hl, hr) = stream_once(&path, &params, Backend::Histogram, tile, false);
        let (sl, sr) = stream_once(&path, &params, Backend::Parallel, tile, false);
        let agreement = hl.iter().zip(&sl).filter(|(a, b)| a == b).count() as f64 / hl.len() as f64;
        let hist = bench(&format!("stream-hist16-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Histogram, tile, false);
        });
        let slab = bench(&format!("stream-slab16-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Parallel, tile, false);
        });
        t16.row([
            name,
            hr.voxels.to_string(),
            fmt_secs(hist.mean()),
            fmt_secs(slab.mean()),
            hr.work_per_iter.to_string(),
            sr.work_per_iter.to_string(),
            (hr.peak_resident_bytes / 1024).to_string(),
            (sr.peak_resident_bytes / 1024).to_string(),
            format!("{agreement:.4}"),
        ]);
        rows16.push(U16Row {
            width: w,
            height: h,
            depth: d,
            voxels: hr.voxels,
            hist,
            slab,
            hist_work: hr.work_per_iter,
            slab_work: sr.work_per_iter,
            hist_peak: hr.peak_resident_bytes,
            slab_peak: sr.peak_resident_bytes,
            agreement,
        });
    }
    t16.print();
    let work_ok = rows16
        .iter()
        .all(|r| r.hist_work == 1 << 16 && r.slab_work == r.voxels);
    println!(
        "\nGATE u16 histogram work level-proportional (65 536 bins at every size): {}",
        if work_ok { "PASS" } else { "FAIL" }
    );

    write_json(&rows, identical, bounded, quick)?;
    write_pr7_u16(&rows16, work_ok, quick)?;
    std::fs::remove_dir_all(&dir).ok();
    if !(identical && bounded && work_ok) {
        anyhow::bail!("streaming gates failed");
    }
    Ok(())
}

struct U16Row {
    width: usize,
    height: usize,
    depth: usize,
    voxels: usize,
    hist: BenchResult,
    slab: BenchResult,
    hist_work: usize,
    slab_work: usize,
    hist_peak: usize,
    slab_peak: usize,
    agreement: f64,
}

/// A genuinely 16-bit phantom RVOL: the 8-bit field spread across the
/// full range (x256) with a deterministic sub-level jitter, so
/// thousands of distinct levels are occupied.
fn make_rvol16(dir: &Path, width: usize, height: usize, depth: usize) -> PathBuf {
    let start = 90usize.min(181 - depth);
    let vol = generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
    .to_voxel_volume();
    let wide: Vec<u16> = vol
        .voxels
        .iter()
        .enumerate()
        .map(|(i, &v)| v as u16 * 256 + (i % 251) as u16)
        .collect();
    let path = dir.join(format!("bench16_{width}x{height}x{depth}.rvol"));
    volume::save_raw_u16(vol.width, vol.height, vol.depth, &wide, &path).unwrap();
    path
}

/// The u16-histogram section of BENCH_PR7.json (shared with the
/// baselines bench's `fused_simd` section — see [`write_pr7_section`]).
fn write_pr7_u16(rows: &[U16Row], work_ok: bool, quick: bool) -> anyhow::Result<()> {
    let mut sizes = String::new();
    for (i, r) in rows.iter().enumerate() {
        sizes.push_str(&format!(
            "{{\"shape\": [{}, {}, {}], \"voxels\": {}, \"stream_hist_s\": {:.6}, \
             \"stream_slab_s\": {:.6}, \"hist_work_per_iter\": {}, \"slab_work_per_iter\": {}, \
             \"hist_peak_bytes\": {}, \"slab_peak_bytes\": {}, \"label_agreement\": {:.4}}}{}",
            r.width,
            r.height,
            r.depth,
            r.voxels,
            r.hist.mean(),
            r.slab.mean(),
            r.hist_work,
            r.slab_work,
            r.hist_peak,
            r.slab_peak,
            r.agreement,
            if i + 1 == rows.len() { "" } else { ", " }
        ));
    }
    let section = format!(
        "{{\"status\": \"measured\", \"quick\": {quick}, \
         \"gate_work_level_proportional\": {work_ok}, \"sizes\": [{sizes}]}}"
    );
    write_pr7_section("histogram_u16", section)
}

/// Rewrite BENCH_PR7.json with our section replaced and the other
/// bench's section (one line per section, by construction) carried over
/// verbatim — the two PR-7 benches share the file without serde. A twin
/// of this helper lives in benches/baselines.rs.
fn write_pr7_section(section: &str, value: String) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR7.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR7.json"),
    };
    let old = std::fs::read_to_string(&path).unwrap_or_default();
    let mut kept = Vec::new();
    for name in ["fused_simd", "histogram_u16"] {
        kept.push(if name == section {
            format!("  \"{name}\": {value}")
        } else {
            old.lines()
                .find(|l| l.trim_start().starts_with(&format!("\"{name}\":")))
                .map(|l| l.trim_end().trim_end_matches(',').to_string())
                .unwrap_or_else(|| format!("  \"{name}\": \"pending\""))
        });
    }
    let s = format!(
        "{{\n  \"pr\": 7,\n  \"bench\": \"fused-simd + histogram-u16\",\n{},\n{}\n}}\n",
        kept[0], kept[1]
    );
    std::fs::write(&path, &s)?;
    println!("wrote {} ({section})", path.display());
    Ok(())
}

/// Record the sweep in BENCH_PR5.json at the repo root (hand-rolled
/// JSON: the offline build has no serde).
fn write_json(rows: &[SizeRow], identical: bool, bounded: bool, quick: bool) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR5.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR5.json"),
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 5,\n");
    s.push_str("  \"bench\": \"streaming\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str(
        "  \"params\": {\"clusters\": 4, \"m\": 2.0, \"epsilon\": 0.005, \"seed\": 42, \
         \"tile_slices\": 4},\n",
    );
    s.push_str(&format!(
        "  \"gates\": {{\"byte_identical\": {identical}, \"peak_depth_independent\": {bounded}}},\n"
    ));
    s.push_str("  \"sizes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let path_json = |b: &BenchResult| {
            format!(
                "{{\"mean_s\": {:.6}, \"p95_s\": {:.6}, \"runs\": {}, \"mvox_per_s\": {:.3}}}",
                b.mean(),
                b.seconds.p95,
                b.runs,
                r.voxels as f64 / b.mean() / 1e6
            )
        };
        s.push_str(&format!(
            "    {{\"shape\": [{}, {}, {}], \"voxels\": {}, \"mem_hist\": {}, \
             \"stream_hist\": {}, \"stream_hist_prefetch\": {}, \"stream_slab\": {}, \
             \"stream_slab_prefetch\": {}, \"stream_spatial\": {}, \
             \"stream_spatial_prefetch\": {}, \"hist_peak_bytes\": {}, \
             \"slab_peak_bytes\": {}, \"spatial_peak_bytes\": {}}}{}\n",
            r.width,
            r.height,
            r.depth,
            r.voxels,
            path_json(&r.mem_hist),
            path_json(&r.stream_hist),
            path_json(&r.stream_hist_pf),
            path_json(&r.stream_slab),
            path_json(&r.stream_slab_pf),
            path_json(&r.stream_spatial),
            path_json(&r.stream_spatial_pf),
            r.hist_peak_bytes,
            r.slab_peak_bytes,
            r.spatial_peak_bytes,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, &s)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
