//! Bench: out-of-core streaming execution — the PR-4 size sweep,
//! extended in PR 5 with the halo-streamed spatial path and the
//! double-buffered tile prefetcher.
//!
//! Sweeps volume sizes over the ways of serving an RVOL file:
//!   * mem-hist        — materialize the file, run the in-memory 3-D
//!     histogram engine (the pre-PR-4 workflow);
//!   * stream-hist     — the truly out-of-core histogram path: two
//!     streaming sweeps + bin-level iterations, resident memory
//!     bounded by the tile;
//!   * stream-slab     — the tile-recompute slab path (re-reads the
//!     file once per iteration; the price of out-of-core voxel-level
//!     FCM);
//!   * stream-spatial  — the halo-streamed spatial path (±1-slice halo
//!     per tile, two re-reads per phase-2 iteration);
//!   * *-pf            — the same streamed paths with a TilePrefetcher
//!     reading tile k+1 while tile k computes (identical output by
//!     construction; the delta is pure I/O overlap).
//!
//! Results (mean/p95, per-voxel throughput, peak resident bytes,
//! prefetch on/off) go to BENCH_PR5.json at the repo root.
//!
//!   cargo bench --bench streaming
//!   REPRO_BENCH_QUICK=1 cargo bench --bench streaming   # CI smoke
//!
//! Gates (on counters and bytes, not clocks):
//!   * streamed labels byte-identical to the in-memory path at EVERY
//!     size, for all three streamed engines, prefetch on AND off;
//!   * stream-hist and stream-spatial peak resident bytes identical
//!     across depths at a fixed tile (bounded by the tile — spatial's
//!     halo adds at most 2 slices — never by the volume).

use repro::fcm::engine::stream::{
    run_streamed, run_streamed_spatial, StreamOpts, StreamRun,
};
use repro::fcm::engine::volume::{run_volume, VolumeOpts};
use repro::fcm::spatial::SpatialParams;
use repro::fcm::{canonical_relabel, spatial, Backend, FcmParams};
use repro::harness::{bench, BenchResult, Opts};
use repro::image::volume::stream::{RvolReader, TilePrefetcher, VoxelSource};
use repro::image::{volume, VoxelVolume};
use repro::phantom::{generate_volume, PhantomConfig};
use repro::report::{fmt_secs, Table};
use std::path::{Path, PathBuf};

struct SizeRow {
    width: usize,
    height: usize,
    depth: usize,
    voxels: usize,
    mem_hist: BenchResult,
    stream_hist: BenchResult,
    stream_hist_pf: BenchResult,
    stream_slab: BenchResult,
    stream_slab_pf: BenchResult,
    stream_spatial: BenchResult,
    stream_spatial_pf: BenchResult,
    hist_peak_bytes: usize,
    slab_peak_bytes: usize,
    spatial_peak_bytes: usize,
    identical: bool,
}

fn make_rvol(dir: &Path, width: usize, height: usize, depth: usize) -> (PathBuf, VoxelVolume) {
    let start = 90usize.min(181 - depth);
    let vol = generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
    .to_voxel_volume();
    let path = dir.join(format!("bench_{width}x{height}x{depth}.rvol"));
    volume::save_raw(&vol, &path).unwrap();
    (path, vol)
}

fn open(path: &Path, prefetch: bool) -> Box<dyn VoxelSource + Send> {
    let src = RvolReader::open(path).unwrap();
    if prefetch {
        Box::new(TilePrefetcher::wrap(src))
    } else {
        Box::new(src)
    }
}

fn stream_once(
    path: &Path,
    params: &FcmParams,
    backend: Backend,
    tile: usize,
    prefetch: bool,
) -> (Vec<u8>, StreamRun) {
    let mut src = open(path, prefetch);
    let mut sink = Vec::new();
    let run = run_streamed(
        &mut *src,
        &mut sink,
        params,
        &StreamOpts {
            backend,
            threads: 0,
            tile_slices: tile,
        },
    )
    .unwrap();
    (sink, run)
}

fn stream_spatial_once(
    path: &Path,
    params: &FcmParams,
    tile: usize,
    prefetch: bool,
) -> (Vec<u8>, StreamRun) {
    let mut src = open(path, prefetch);
    let mut sink = Vec::new();
    let run = run_streamed_spatial(
        &mut *src,
        &mut sink,
        params,
        &SpatialParams::default(),
        &StreamOpts {
            backend: Backend::Parallel,
            threads: 0,
            tile_slices: tile,
        },
    )
    .unwrap();
    (sink, run)
}

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_BENCH_QUICK").is_ok();
    let params = FcmParams::default();
    let tile = 4usize;
    let sizes: Vec<(usize, usize, usize)> = if quick {
        vec![(91, 109, 10)]
    } else {
        vec![(91, 109, 10), (181, 217, 10), (181, 217, 40)]
    };
    let opts = Opts {
        warmup: 1,
        min_runs: 3,
        max_runs: if quick { 3 } else { 5 },
        max_seconds: 60.0,
    };
    let dir = std::env::temp_dir().join(format!("stream_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    println!("== out-of-core streaming: mem-hist vs stream-{{hist,slab,spatial}} x prefetch ==\n");
    let mut t = Table::new([
        "volume",
        "voxels",
        "mem-hist",
        "s-hist",
        "s-hist-pf",
        "s-slab",
        "s-slab-pf",
        "s-spatial",
        "s-spatial-pf",
        "hist KB",
        "slab KB",
        "spatial KB",
        "identical",
    ]);
    let mut rows = Vec::new();
    for &(w, h, d) in &sizes {
        let (path, vol) = make_rvol(&dir, w, h, d);
        let name = format!("{w}x{h}x{d}");

        // Equivalence + metadata from untimed runs: every streamed
        // engine, prefetch on AND off, against its in-memory twin.
        let mut mem = run_volume(&vol, &params, &VolumeOpts::with_backend(Backend::Histogram));
        canonical_relabel(&mut mem.run);
        let mut mem_slab = run_volume(&vol, &params, &VolumeOpts::default());
        canonical_relabel(&mut mem_slab.run);
        let mut mem_spatial = spatial::run_volume(
            &vol,
            &params,
            &SpatialParams::default(),
            &VolumeOpts::default(),
        );
        canonical_relabel(&mut mem_spatial.run);
        let (hist_labels, hist_run) = stream_once(&path, &params, Backend::Histogram, tile, false);
        let (hist_pf, _) = stream_once(&path, &params, Backend::Histogram, tile, true);
        let (slab_labels, slab_run) = stream_once(&path, &params, Backend::Parallel, tile, false);
        let (slab_pf, _) = stream_once(&path, &params, Backend::Parallel, tile, true);
        let (spatial_labels, spatial_run) = stream_spatial_once(&path, &params, tile, false);
        let (spatial_pf, _) = stream_spatial_once(&path, &params, tile, true);
        let identical = hist_labels == mem.run.labels
            && hist_pf == mem.run.labels
            && slab_labels == mem_slab.run.labels
            && slab_pf == mem_slab.run.labels
            && spatial_labels == mem_spatial.run.labels
            && spatial_pf == mem_spatial.run.labels;

        let mem_hist = bench(&format!("mem-hist-{name}"), &opts, || {
            let v = volume::load_raw(&path).unwrap();
            let _ = run_volume(&v, &params, &VolumeOpts::with_backend(Backend::Histogram));
        });
        let stream_hist = bench(&format!("stream-hist-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Histogram, tile, false);
        });
        let stream_hist_pf = bench(&format!("stream-hist-pf-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Histogram, tile, true);
        });
        let stream_slab = bench(&format!("stream-slab-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Parallel, tile, false);
        });
        let stream_slab_pf = bench(&format!("stream-slab-pf-{name}"), &opts, || {
            let _ = stream_once(&path, &params, Backend::Parallel, tile, true);
        });
        let stream_spatial = bench(&format!("stream-spatial-{name}"), &opts, || {
            let _ = stream_spatial_once(&path, &params, tile, false);
        });
        let stream_spatial_pf = bench(&format!("stream-spatial-pf-{name}"), &opts, || {
            let _ = stream_spatial_once(&path, &params, tile, true);
        });

        t.row([
            name,
            vol.len().to_string(),
            fmt_secs(mem_hist.mean()),
            fmt_secs(stream_hist.mean()),
            fmt_secs(stream_hist_pf.mean()),
            fmt_secs(stream_slab.mean()),
            fmt_secs(stream_slab_pf.mean()),
            fmt_secs(stream_spatial.mean()),
            fmt_secs(stream_spatial_pf.mean()),
            (hist_run.peak_resident_bytes / 1024).to_string(),
            (slab_run.peak_resident_bytes / 1024).to_string(),
            (spatial_run.peak_resident_bytes / 1024).to_string(),
            identical.to_string(),
        ]);
        rows.push(SizeRow {
            width: w,
            height: h,
            depth: d,
            voxels: vol.len(),
            mem_hist,
            stream_hist,
            stream_hist_pf,
            stream_slab,
            stream_slab_pf,
            stream_spatial,
            stream_spatial_pf,
            hist_peak_bytes: hist_run.peak_resident_bytes,
            slab_peak_bytes: slab_run.peak_resident_bytes,
            spatial_peak_bytes: spatial_run.peak_resident_bytes,
            identical,
        });
    }
    t.print();

    // Gate 1: byte identity at every size, all engines, prefetch on/off.
    let identical = rows.iter().all(|r| r.identical);
    println!(
        "\nGATE streamed output byte-identical to in-memory at every size: {}",
        if identical { "PASS" } else { "FAIL" }
    );

    // Gate 2: stream-hist AND stream-spatial peak resident bytes
    // independent of depth at a fixed tile and resolution (the
    // out-of-core claim, on a counter; spatial's halo adds slices to
    // the tile, never depth-dependence).
    let peaks_at = |depth: usize| {
        let (path, _) = make_rvol(&dir, 91, 109, depth);
        let hist = stream_once(&path, &params, Backend::Histogram, 2, false).1;
        let spat = stream_spatial_once(&path, &params, 2, false).1;
        (hist.peak_resident_bytes, spat.peak_resident_bytes)
    };
    let (h_a, s_a) = peaks_at(6);
    let (h_b, s_b) = peaks_at(48);
    let bounded = h_a == h_b && s_a == s_b;
    println!(
        "GATE streamed peak resident bytes depth-independent: {} \
         (hist {h_a} vs {h_b}, spatial {s_a} vs {s_b})",
        if bounded { "PASS" } else { "FAIL" }
    );

    write_json(&rows, identical, bounded, quick)?;
    std::fs::remove_dir_all(&dir).ok();
    if !(identical && bounded) {
        anyhow::bail!("streaming gates failed");
    }
    Ok(())
}

/// Record the sweep in BENCH_PR5.json at the repo root (hand-rolled
/// JSON: the offline build has no serde).
fn write_json(rows: &[SizeRow], identical: bool, bounded: bool, quick: bool) -> anyhow::Result<()> {
    let path = match std::env::var("CARGO_MANIFEST_DIR") {
        Ok(dir) => std::path::Path::new(&dir).join("../BENCH_PR5.json"),
        Err(_) => std::path::PathBuf::from("BENCH_PR5.json"),
    };
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"pr\": 5,\n");
    s.push_str("  \"bench\": \"streaming\",\n");
    s.push_str("  \"status\": \"measured\",\n");
    s.push_str(&format!("  \"quick\": {quick},\n"));
    s.push_str(
        "  \"params\": {\"clusters\": 4, \"m\": 2.0, \"epsilon\": 0.005, \"seed\": 42, \
         \"tile_slices\": 4},\n",
    );
    s.push_str(&format!(
        "  \"gates\": {{\"byte_identical\": {identical}, \"peak_depth_independent\": {bounded}}},\n"
    ));
    s.push_str("  \"sizes\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let path_json = |b: &BenchResult| {
            format!(
                "{{\"mean_s\": {:.6}, \"p95_s\": {:.6}, \"runs\": {}, \"mvox_per_s\": {:.3}}}",
                b.mean(),
                b.seconds.p95,
                b.runs,
                r.voxels as f64 / b.mean() / 1e6
            )
        };
        s.push_str(&format!(
            "    {{\"shape\": [{}, {}, {}], \"voxels\": {}, \"mem_hist\": {}, \
             \"stream_hist\": {}, \"stream_hist_prefetch\": {}, \"stream_slab\": {}, \
             \"stream_slab_prefetch\": {}, \"stream_spatial\": {}, \
             \"stream_spatial_prefetch\": {}, \"hist_peak_bytes\": {}, \
             \"slab_peak_bytes\": {}, \"spatial_peak_bytes\": {}}}{}\n",
            r.width,
            r.height,
            r.depth,
            r.voxels,
            path_json(&r.mem_hist),
            path_json(&r.stream_hist),
            path_json(&r.stream_hist_pf),
            path_json(&r.stream_slab),
            path_json(&r.stream_slab_pf),
            path_json(&r.stream_spatial),
            path_json(&r.stream_spatial_pf),
            r.hist_peak_bytes,
            r.slab_peak_bytes,
            r.spatial_peak_bytes,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    std::fs::write(&path, &s)?;
    println!("\nwrote {}", path.display());
    Ok(())
}
