//! Integration tests for the networked serving front door: protocol
//! robustness (fuzz-shaped malformed-frame sweep), end-to-end byte
//! identity with the in-process path, typed error-code round-trips,
//! connection backpressure, result retention, and the drained
//! accounting identity with remote submitters.

use repro::config::Config;
use repro::coordinator::{Engine, Service};
use repro::fcm::FcmParams;
use repro::image::{volume, VoxelVolume};
use repro::net::protocol::{
    decode_reply, encode_request, read_frame, write_frame, MAX_FRAME,
};
use repro::net::{Client, ErrorCode, JobState, RemoteError, Reply, Request, Server, SubmitJob, SubmitPayload};
use repro::phantom::{generate_slice, generate_volume, PhantomConfig};
use std::io::Write as _;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("net_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn cfg(workers: usize, queue_depth: usize) -> Config {
    let mut cfg = Config::new();
    cfg.service.workers = workers;
    cfg.service.queue_depth = queue_depth;
    cfg
}

/// Bind a server over a fresh service on an ephemeral port.
fn start_server(cfg: &Config, max_connections: usize) -> (Server, String) {
    let service = Arc::new(Service::start(cfg).unwrap());
    let server = Server::bind(service, "127.0.0.1:0", max_connections).unwrap();
    let addr = server.local_addr().to_string();
    (server, addr)
}

fn phantom_image_payload(seed: u64) -> SubmitPayload {
    let s = generate_slice(&PhantomConfig { seed, ..PhantomConfig::default() });
    SubmitPayload::Image {
        width: s.image.width as u32,
        height: s.image.height as u32,
        pixels: s.image.pixels,
    }
}

fn submit_job(engine: Engine, params: FcmParams, payload: SubmitPayload) -> SubmitJob {
    SubmitJob { engine, priority: Default::default(), params, payload }
}

/// Quick params: converge fast on phantom data.
fn quick_params() -> FcmParams {
    FcmParams { clusters: 3, max_iters: 30, ..FcmParams::default() }
}

/// Slow params: epsilon 0 never converges, so the job runs its full
/// iteration budget — the worker-occupying blocker.
fn slow_params(iters: usize) -> FcmParams {
    FcmParams { clusters: 3, epsilon: 0.0, max_iters: iters, ..FcmParams::default() }
}

#[test]
fn ping_submit_status_fetch_roundtrip() {
    let (server, addr) = start_server(&cfg(1, 8), 8);
    let mut c = Client::connect(&addr).unwrap();
    c.ping().unwrap();
    let id = c
        .submit(submit_job(Engine::Histogram, quick_params(), phantom_image_payload(1)))
        .unwrap();
    let res = c.wait(id, Duration::from_millis(20), Duration::from_secs(60)).unwrap();
    assert_eq!(res.id, id);
    assert_eq!(res.shape.2, 1, "image jobs report depth 1");
    assert_eq!(res.clusters, 3);
    assert_eq!(
        res.labels.len(),
        res.shape.0 as usize * res.shape.1 as usize,
        "one label per pixel"
    );
    assert!(res.iterations > 0);
    // Status after completion still answers (result retained).
    assert_eq!(c.status(id).unwrap(), JobState::Done);
    // Metrics exposition is fetchable over the wire and mentions the
    // net counters.
    let prom = c.metrics().unwrap();
    assert!(prom.contains("repro_net_connections_total"));
    assert!(prom.contains("repro_jobs_submitted_total"));
    let snap = server.shutdown().unwrap();
    assert_eq!(snap.submitted, snap.completed + snap.failed + snap.cancelled);
    assert!(snap.net_connections >= 1);
    assert!(snap.net_frames > 0);
    assert!(snap.net_bytes_in > 0 && snap.net_bytes_out > 0);
}

/// The acceptance pin: a volume submitted over TCP, fetched, and
/// rendered client-side is byte-identical to the same job run fully
/// in-process (same engine, same params, same rendering calls).
#[test]
fn remote_fetch_is_byte_identical_to_in_process() {
    let dir = tmp_dir("identity");
    let params = quick_params();
    let pv = generate_volume(&PhantomConfig::default(), 88, 96, 1);
    let vol = pv.to_voxel_volume();

    // In-process run, rendered exactly as `segment-volume --out-raw`.
    let local = dir.join("local.rvol");
    {
        let service = Service::start(&cfg(1, 8)).unwrap();
        let t = service.submit_volume(vol.clone(), params, Engine::Histogram).unwrap();
        let r = t.wait().unwrap();
        let seg = VoxelVolume::from_labels(
            vol.width,
            vol.height,
            vol.depth,
            &r.labels,
            params.clusters as u8,
        );
        volume::save_raw(&seg, &local).unwrap();
        service.shutdown();
    }

    // Remote run: submit the same voxels over the wire, poll, fetch,
    // render through the same calls.
    let remote = dir.join("remote.rvol");
    let (server, addr) = start_server(&cfg(1, 8), 8);
    let mut c = Client::connect(&addr).unwrap();
    let payload = SubmitPayload::Volume {
        width: vol.width as u32,
        height: vol.height as u32,
        depth: vol.depth as u32,
        voxels: vol.voxels.clone(),
    };
    let id = c.submit(submit_job(Engine::Histogram, params, payload)).unwrap();
    let res = c.wait(id, Duration::from_millis(20), Duration::from_secs(120)).unwrap();
    assert_eq!(
        (res.shape.0 as usize, res.shape.1 as usize, res.shape.2 as usize),
        (vol.width, vol.height, vol.depth)
    );
    let seg = VoxelVolume::from_labels(
        vol.width,
        vol.height,
        vol.depth,
        &res.labels,
        res.clusters as u8,
    );
    volume::save_raw(&seg, &remote).unwrap();
    server.shutdown().unwrap();

    let a = std::fs::read(&local).unwrap();
    let b = std::fs::read(&remote).unwrap();
    assert!(!a.is_empty());
    assert_eq!(a, b, "remote fetch must render byte-identical output");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fuzz-shaped rejection sweep: truncated frames, oversized declared
/// lengths, unknown tags, bad field values, trailing bytes, and
/// mid-frame disconnects. The server must answer with typed errors or
/// drop the one connection — and keep serving everyone else. No worker
/// panics: a clean graceful shutdown still works afterwards.
#[test]
fn malformed_frames_never_take_the_server_down() {
    let (server, addr) = start_server(&cfg(1, 8), 16);

    // 1. Unknown tag: typed BadRequest reply on the same connection.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        write_frame(&mut s, &[0x42]).unwrap();
        let payload = read_frame(&mut s).unwrap().unwrap();
        match decode_reply(&payload).unwrap() {
            Reply::Error { code, message } => {
                assert_eq!(code, ErrorCode::BadRequest);
                assert!(message.contains("unknown message tag"), "{message}");
            }
            r => panic!("expected error reply, got {r:?}"),
        }
    }

    // 2. Trailing bytes after a complete message: typed BadRequest.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut payload = encode_request(&Request::Ping);
        payload.extend_from_slice(&[1, 2, 3]);
        write_frame(&mut s, &payload).unwrap();
        let reply = decode_reply(&read_frame(&mut s).unwrap().unwrap()).unwrap();
        assert!(matches!(reply, Reply::Error { code: ErrorCode::BadRequest, .. }));
    }

    // 3. Truncated body: tag says status but the id is cut short.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut enc = encode_request(&Request::Status { id: 1 });
        enc.truncate(4);
        write_frame(&mut s, &enc).unwrap();
        let reply = decode_reply(&read_frame(&mut s).unwrap().unwrap()).unwrap();
        assert!(matches!(reply, Reply::Error { code: ErrorCode::BadRequest, .. }));
    }

    // 4. Oversized declared length: the server refuses to allocate and
    // drops the connection (read returns EOF/reset, not a 2 GiB buffer).
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&(MAX_FRAME + 1).to_le_bytes()).unwrap();
        s.flush().unwrap();
        assert!(
            matches!(read_frame(&mut s), Ok(None) | Err(_)),
            "connection should be dropped, not served"
        );
    }

    // 5. Mid-frame disconnect: declare 100 bytes, send 10, hang up.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&100u32.to_le_bytes()).unwrap();
        s.write_all(&[0u8; 10]).unwrap();
        s.flush().unwrap();
        drop(s);
    }

    // 6. Disconnect inside the length prefix itself.
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(&[7u8, 0]).unwrap();
        s.flush().unwrap();
        drop(s);
    }

    // 7. Bad field value inside a structurally-valid submit (engine
    // byte out of range).
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        let mut enc = encode_request(&Request::Submit(submit_job(
            Engine::Parallel,
            quick_params(),
            SubmitPayload::Image { width: 1, height: 1, pixels: vec![7] },
        )));
        enc[2] = 250; // engine byte
        write_frame(&mut s, &enc).unwrap();
        let reply = decode_reply(&read_frame(&mut s).unwrap().unwrap()).unwrap();
        assert!(matches!(reply, Reply::Error { code: ErrorCode::BadRequest, .. }));
    }

    // After the whole sweep the server still serves real work…
    let mut c = Client::connect(&addr).unwrap();
    c.ping().unwrap();
    let id = c
        .submit(submit_job(Engine::Parallel, quick_params(), phantom_image_payload(3)))
        .unwrap();
    c.wait(id, Duration::from_millis(20), Duration::from_secs(60)).unwrap();
    // …and still shuts down gracefully (no worker died mid-sweep).
    let snap = server.shutdown().unwrap();
    assert_eq!(snap.submitted, snap.completed + snap.failed + snap.cancelled);
    assert_eq!(snap.completed, 1);
    assert!(snap.net_errors > 0, "the sweep must have counted wire errors");
}

/// A client that submits into a full queue observes **backpressure**:
/// the submit blocks until a slot frees, then succeeds. It never gets
/// an error, and the server never buffers unboundedly.
#[test]
fn full_queue_blocks_the_submitter_instead_of_failing() {
    // One worker, one queue slot: blocker executes, filler waits in the
    // queue, the third submit must block inside the server handler.
    let (server, addr) = start_server(&cfg(1, 1), 8);
    let mut c = Client::connect(&addr).unwrap();
    let blocker = c
        .submit(submit_job(Engine::Sequential, slow_params(400), phantom_image_payload(10)))
        .unwrap();
    let filler = c
        .submit(submit_job(Engine::Sequential, slow_params(400), phantom_image_payload(11)))
        .unwrap();
    let addr2 = addr.clone();
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        let mut c2 = Client::connect(&addr2).unwrap();
        let r = c2.submit(submit_job(
            Engine::Sequential,
            slow_params(400),
            phantom_image_payload(12),
        ));
        let _ = tx.send(());
        r
    });
    // While the blocker occupies the worker and the filler the queue
    // slot, the third submit must still be waiting — blocked, not
    // bounced with an error.
    assert!(
        rx.recv_timeout(Duration::from_millis(120)).is_err(),
        "submit into a full queue should block (backpressure), not return"
    );
    // It resolves once capacity frees up — successfully.
    let third = h.join().unwrap().expect("blocked submit must eventually succeed");
    let mut ids = vec![blocker, filler, third];
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 3, "three distinct job ids");
    for id in ids {
        c.wait(id, Duration::from_millis(20), Duration::from_secs(120)).unwrap();
    }
    let snap = server.shutdown().unwrap();
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.submitted, snap.completed + snap.failed + snap.cancelled);
}

/// The serving-path error taxonomy round-trips as distinct codes.
#[test]
fn error_codes_roundtrip_distinctly() {
    let dir = tmp_dir("codes");

    // NotFound / NotReady.
    {
        let (server, addr) = start_server(&cfg(1, 8), 8);
        let mut c = Client::connect(&addr).unwrap();
        let e = c.fetch(99_999).unwrap_err();
        assert_eq!(e.downcast_ref::<RemoteError>().unwrap().code, ErrorCode::NotFound);
        let e = c.status(99_999).unwrap_err();
        assert_eq!(e.downcast_ref::<RemoteError>().unwrap().code, ErrorCode::NotFound);
        let id = c
            .submit(submit_job(Engine::Sequential, slow_params(300), phantom_image_payload(20)))
            .unwrap();
        let e = c.fetch(id).unwrap_err();
        assert_eq!(e.downcast_ref::<RemoteError>().unwrap().code, ErrorCode::NotReady);
        c.wait(id, Duration::from_millis(20), Duration::from_secs(120)).unwrap();
        server.shutdown().unwrap();
    }

    // AdmissionRejected: a streamed submit against a 1-byte resident
    // budget is rejected with the typed code.
    {
        let input = dir.join("in.rvol");
        let pv = generate_volume(&PhantomConfig::default(), 88, 92, 1);
        volume::save_raw(&pv.to_voxel_volume(), &input).unwrap();
        let mut c1 = cfg(1, 8);
        c1.service.resident_budget_bytes = 1;
        let (server, addr) = start_server(&c1, 8);
        let mut c = Client::connect(&addr).unwrap();
        let out = dir.join("out.rvol");
        let e = c
            .submit(submit_job(
                Engine::Histogram,
                quick_params(),
                SubmitPayload::Stream {
                    input: input.display().to_string(),
                    mask: None,
                    output: out.display().to_string(),
                    tile_slices: 2,
                    prefetch: false,
                },
            ))
            .unwrap_err();
        let remote = e.downcast_ref::<RemoteError>().unwrap();
        assert_eq!(remote.code, ErrorCode::AdmissionRejected);
        assert!(remote.message.contains("budget"), "{}", remote.message);
        server.shutdown().unwrap();
    }

    // DeadlineExceeded: a 1 ms job timeout fires mid-run; the stored
    // failure replays its typed code on fetch.
    {
        let mut c1 = cfg(1, 8);
        c1.service.job_timeout_ms = 1;
        let (server, addr) = start_server(&c1, 8);
        let mut c = Client::connect(&addr).unwrap();
        let id = c
            .submit(submit_job(Engine::Sequential, slow_params(5_000), phantom_image_payload(21)))
            .unwrap();
        let e = c
            .wait(id, Duration::from_millis(20), Duration::from_secs(120))
            .unwrap_err();
        assert_eq!(
            e.downcast_ref::<RemoteError>().unwrap().code,
            ErrorCode::DeadlineExceeded
        );
        assert_eq!(c.status(id).unwrap(), JobState::Failed);
        let snap = server.shutdown().unwrap();
        assert_eq!(snap.cancelled, 1, "deadline counts as cancelled, not failed");
        assert_eq!(snap.submitted, snap.completed + snap.failed + snap.cancelled);
    }

    // TooManyConnections: past the cap, the server answers with the
    // typed code and closes.
    {
        let (server, addr) = start_server(&cfg(1, 8), 1);
        let mut first = Client::connect(&addr).unwrap();
        first.ping().unwrap();
        // Past the cap the server volunteers the error frame and closes;
        // read it raw rather than racing a request against the close.
        let mut second = TcpStream::connect(&addr).unwrap();
        let payload = read_frame(&mut second).unwrap().expect("error frame before close");
        match decode_reply(&payload).unwrap() {
            Reply::Error { code, .. } => assert_eq!(code, ErrorCode::TooManyConnections),
            r => panic!("expected error reply, got {r:?}"),
        }
        // The first connection is unaffected.
        first.ping().unwrap();
        server.shutdown().unwrap();
    }

    let _ = std::fs::remove_dir_all(&dir);
}

/// Completed results are retained for repeat fetches, and age out after
/// the retention TTL.
#[test]
fn results_are_retained_then_expire() {
    let service = Arc::new(Service::start(&cfg(1, 8)).unwrap());
    let server = Server::bind_with_retention(
        service,
        "127.0.0.1:0",
        8,
        Duration::from_millis(150),
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let mut c = Client::connect(&addr).unwrap();
    let id = c
        .submit(submit_job(Engine::Histogram, quick_params(), phantom_image_payload(30)))
        .unwrap();
    let first = c.wait(id, Duration::from_millis(20), Duration::from_secs(60)).unwrap();
    // Repeat fetch: identical bytes (retained, not consumed).
    let second = c.fetch(id).unwrap();
    assert_eq!(first.labels, second.labels);
    assert_eq!(first.centers, second.centers);
    std::thread::sleep(Duration::from_millis(300));
    let e = c.fetch(id).unwrap_err();
    assert_eq!(e.downcast_ref::<RemoteError>().unwrap().code, ErrorCode::NotFound);
    server.shutdown().unwrap();
}

/// Soak: concurrent remote submitters (plus an in-process one sharing
/// the same service) all complete, and the drained snapshot preserves
/// the accounting identity `submitted == completed + failed +
/// cancelled` with the net counters consistent.
#[test]
fn soak_accounting_identity_with_remote_submitters() {
    const CLIENTS: usize = 4;
    const JOBS_PER_CLIENT: usize = 6;
    let service = Arc::new(Service::start(&cfg(2, 8)).unwrap());
    let inproc = Arc::clone(&service);
    let server = Server::bind(service, "127.0.0.1:0", 16).unwrap();
    let addr = server.local_addr().to_string();

    let engines = [Engine::Sequential, Engine::Parallel, Engine::Histogram];
    let threads: Vec<_> = (0..CLIENTS)
        .map(|t| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(&addr).unwrap();
                let mut done = 0usize;
                for j in 0..JOBS_PER_CLIENT {
                    let engine = engines[(t + j) % engines.len()];
                    let id = c
                        .submit(submit_job(
                            engine,
                            quick_params(),
                            phantom_image_payload((t * 100 + j) as u64),
                        ))
                        .unwrap();
                    let res =
                        c.wait(id, Duration::from_millis(10), Duration::from_secs(120)).unwrap();
                    assert!(!res.labels.is_empty());
                    done += 1;
                }
                done
            })
        })
        .collect();
    // In-process submissions share the queue with the remote ones.
    let mut local_done = 0usize;
    for j in 0..JOBS_PER_CLIENT {
        let s = generate_slice(&PhantomConfig { seed: 900 + j as u64, ..PhantomConfig::default() });
        let t = inproc.submit_image(&s.image, quick_params(), Engine::Parallel).unwrap();
        t.wait().unwrap();
        local_done += 1;
    }
    drop(inproc); // the server must be the last Service holder at shutdown
    let remote_done: usize = threads.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(remote_done, CLIENTS * JOBS_PER_CLIENT);

    let snap = server.shutdown().unwrap();
    assert_eq!(
        snap.submitted,
        snap.completed + snap.failed + snap.cancelled,
        "drained accounting identity"
    );
    assert_eq!(snap.completed as usize, remote_done + local_done);
    assert_eq!(snap.failed, 0);
    assert!(snap.net_connections >= CLIENTS as u64);
    // Every request frame got exactly one reply frame, so the frame
    // count is even and split across both directions.
    assert!(snap.net_bytes_in > 0 && snap.net_bytes_out > 0);
    assert_eq!(snap.net_errors, 0, "clean soak: no wire errors");
}
