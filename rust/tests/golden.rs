//! Golden-fixture regression suite: every serving engine, in-memory
//! AND streamed, byte-compared against committed expected label bytes.
//!
//! The fixtures under `tests/fixtures/` are tiny deterministic volumes
//! (an 8×8×6 RVOL, a paired mask RVOL, a 3-slice PGM stack) whose
//! expected labels per engine were derived from the engines' defined
//! arithmetic by the bit-exact mirror in `fixtures/gen_fixtures.py`
//! (wide singularity/epsilon/argmax margins asserted at generation
//! time). Because the bytes are committed, ANY cross-PR drift in
//! engine output — init stream, reduction order, canonicalization,
//! sentinel pinning, streaming equivalence — fails here immediately,
//! without re-deriving anything on a toolchain machine.
//!
//! Intended output changes are re-blessed with
//! `REPRO_BLESS=1 cargo test --test golden` (rewrites the expected
//! files from the in-memory engines; review the diff) or by re-running
//! the python generator.

mod common;

use repro::coordinator::{backend_for, Engine};
use repro::fcm::{EngineOpts, FcmParams};
use repro::image::volume::stream::{PgmStackSource, RvolReader, TilePrefetcher, VoxelSource};
use repro::image::{volume, VoxelVolume};
use std::path::{Path, PathBuf};

const ENGINES: [(Engine, &str); 4] = [
    (Engine::Sequential, "sequential"),
    (Engine::Parallel, "parallel"),
    (Engine::Histogram, "histogram"),
    (Engine::Spatial, "spatial"),
];

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

fn fixture_volume(masked: bool) -> VoxelVolume {
    let vol = volume::load_raw(&fixtures().join("vol.rvol")).unwrap();
    if masked {
        let mask = volume::load_raw(&fixtures().join("mask.rvol")).unwrap();
        vol.with_mask(mask.voxels)
    } else {
        vol
    }
}

fn expected(name: &str) -> Vec<u8> {
    let path = fixtures().join("expected").join(name);
    std::fs::read(&path).unwrap_or_else(|e| panic!("missing fixture {}: {e}", path.display()))
}

fn opts() -> EngineOpts {
    EngineOpts {
        threads: common::engine_threads(),
        ..EngineOpts::default()
    }
}

fn label_file(name: &str, masked: bool) -> String {
    if masked {
        format!("{name}_masked.labels")
    } else {
        format!("{name}.labels")
    }
}

fn blessing() -> bool {
    std::env::var("REPRO_BLESS").is_ok()
}

/// Compare against the committed bytes — or, under REPRO_BLESS, rewrite
/// them (only this in-memory path blesses, so parallel test threads
/// never race on the files).
fn check_or_bless(name: &str, got: &[u8]) {
    let path = fixtures().join("expected").join(name);
    if blessing() {
        std::fs::write(&path, got).unwrap();
        return;
    }
    assert_eq!(
        got,
        &expected(name)[..],
        "{name}: engine output drifted from the golden fixture \
         (REPRO_BLESS=1 re-blesses after verifying the change is intended)"
    );
}

#[test]
fn golden_in_memory_engines_match_fixtures() {
    let params = FcmParams::default();
    for masked in [false, true] {
        let vol = fixture_volume(masked);
        for (engine, name) in ENGINES {
            let backend = backend_for(engine, None, &opts()).unwrap();
            let out = backend.segment_volume(&vol, &params).unwrap();
            assert_eq!(out.labels.len(), vol.len(), "{engine:?}");
            check_or_bless(&label_file(name, masked), &out.labels);
        }
    }
}

#[test]
fn golden_streamed_engines_match_fixtures() {
    // Every engine through segment_volume_streamed (the host overrides
    // run out of core; Sequential exercises the materialize fallback),
    // across two tile sizes. Under REPRO_BLESS the reference is the
    // in-memory run instead of the file (the bless happens there).
    let params = FcmParams::default();
    for masked in [false, true] {
        let vol = fixture_volume(masked);
        for (engine, name) in ENGINES {
            let backend = backend_for(engine, None, &opts()).unwrap();
            let want = if blessing() {
                backend.segment_volume(&vol, &params).unwrap().labels
            } else {
                expected(&label_file(name, masked))
            };
            for tile in [1usize, 2] {
                let mut src = vol.clone();
                let mut sink = Vec::new();
                backend
                    .segment_volume_streamed(&mut src, &mut sink, &params, tile)
                    .unwrap();
                assert_eq!(sink, want, "{engine:?} tile {tile} masked {masked}");
            }
        }
    }
}

#[test]
fn golden_file_backed_stream_matches_fixtures() {
    // The real file path: RvolReader (with the paired mask), wrapped in
    // the prefetcher — bytes must still equal the committed labels.
    if blessing() {
        return;
    }
    let params = FcmParams::default();
    let vp = fixtures().join("vol.rvol");
    let mp = fixtures().join("mask.rvol");
    for (engine, name) in [(Engine::Parallel, "parallel"), (Engine::Spatial, "spatial")] {
        let backend = backend_for(engine, None, &opts()).unwrap();
        let mut src = TilePrefetcher::wrap(RvolReader::with_mask(&vp, &mp).unwrap());
        let mut sink = Vec::new();
        backend
            .segment_volume_streamed(&mut src, &mut sink, &params, 2)
            .unwrap();
        assert_eq!(
            sink,
            expected(&label_file(name, true)),
            "{engine:?} file-backed prefetched stream"
        );
    }
}

#[test]
fn golden_u16_streamed_engines_match_fixtures() {
    // The 16-bit RVOL is streaming-only (parse_raw rejects it in
    // memory): the slab and wide-bin (65 536) histogram engines read it
    // through RvolReader and must land on the mirror's committed
    // labels, for any tile size, with and without the prefetcher.
    if blessing() {
        return;
    }
    let params = FcmParams::default();
    let vp = fixtures().join("vol16.rvol");
    for (engine, name) in [
        (Engine::Parallel, "parallel_u16.labels"),
        (Engine::Histogram, "histogram_u16.labels"),
    ] {
        let backend = backend_for(engine, None, &opts()).unwrap();
        let want = expected(name);
        for tile in [1usize, 2, 6] {
            let mut src: Box<dyn VoxelSource + Send> = if tile % 2 == 0 {
                Box::new(TilePrefetcher::wrap(RvolReader::open(&vp).unwrap()))
            } else {
                Box::new(RvolReader::open(&vp).unwrap())
            };
            let mut sink = Vec::new();
            backend
                .segment_volume_streamed(&mut *src, &mut sink, &params, tile)
                .unwrap();
            assert_eq!(sink, want, "{engine:?} u16 tile {tile}");
        }
    }
}

#[test]
fn golden_simd_toggle_is_result_neutral() {
    // The scalar and vector kernels are bit-identical by contract;
    // prove it end-to-end by running the whole engine set against the
    // fixtures with the vector kernel forced off, then forced on. The
    // toggle is process-global but result-neutral, so flipping it here
    // cannot perturb concurrently running tests.
    if blessing() {
        return;
    }
    let params = FcmParams::default();
    for simd in [false, true] {
        repro::fcm::engine::fused::set_simd(simd);
        for masked in [false, true] {
            let vol = fixture_volume(masked);
            for (engine, name) in ENGINES {
                let backend = backend_for(engine, None, &opts()).unwrap();
                let out = backend.segment_volume(&vol, &params).unwrap();
                assert_eq!(
                    out.labels,
                    expected(&label_file(name, masked)),
                    "{engine:?} masked {masked} simd {simd}"
                );
            }
        }
    }
    // Hand the process back to the env-resolved default (the CI
    // simd-matrix leg pins REPRO_SIMD for the whole test binary).
    let default_on = match std::env::var("REPRO_SIMD") {
        Ok(v) => !(v == "0" || v.eq_ignore_ascii_case("false") || v.eq_ignore_ascii_case("off")),
        Err(_) => true,
    };
    repro::fcm::engine::fused::set_simd(default_on);
}

#[test]
fn golden_pgm_stack_in_memory_and_streamed() {
    let params = FcmParams::default();
    let dir = fixtures().join("stack");
    let backend = backend_for(Engine::Parallel, None, &opts()).unwrap();
    let vol = volume::load_pgm_stack(&dir).unwrap();
    assert_eq!((vol.width, vol.height, vol.depth), (8, 8, 3));
    let out = backend.segment_volume(&vol, &params).unwrap();
    check_or_bless("stack_parallel.labels", &out.labels);
    // The streamed PGM-stack seam lands on the same bytes.
    let want = if blessing() {
        out.labels
    } else {
        expected("stack_parallel.labels")
    };
    for tile in [1usize, 2, 3] {
        let mut src = PgmStackSource::open(&dir).unwrap();
        let mut sink = Vec::new();
        backend
            .segment_volume_streamed(&mut src, &mut sink, &params, tile)
            .unwrap();
        assert_eq!(sink, want, "PGM stack streamed, tile {tile}");
    }
}

#[test]
fn golden_tracing_is_result_neutral() {
    // The observability acceptance gate against committed bytes: every
    // engine, in-memory and streamed, with the thread-local profiler
    // armed, must land on exactly the golden fixtures. (The CI
    // REPRO_TRACE=1 leg re-runs the whole suite auto-armed; this test
    // pins the property even in an untraced run.)
    if blessing() {
        return;
    }
    let params = FcmParams::default();
    for masked in [false, true] {
        let vol = fixture_volume(masked);
        for (engine, name) in ENGINES {
            let backend = backend_for(engine, None, &opts()).unwrap();

            repro::obs::prof::begin(2 * params.max_iters);
            let out = backend.segment_volume(&vol, &params).unwrap();
            let profile = repro::obs::prof::take().expect("profile armed");
            assert_eq!(
                out.labels,
                expected(&label_file(name, masked)),
                "{engine:?} masked {masked} drifted under tracing (in-memory)"
            );
            assert!(!profile.iters.is_empty(), "{engine:?} recorded no iterations");

            repro::obs::prof::begin(2 * params.max_iters);
            let mut src = vol.clone();
            let mut sink = Vec::new();
            backend
                .segment_volume_streamed(&mut src, &mut sink, &params, 2)
                .unwrap();
            repro::obs::prof::take().expect("profile armed");
            assert_eq!(
                sink,
                expected(&label_file(name, masked)),
                "{engine:?} masked {masked} drifted under tracing (streamed)"
            );
        }
    }
}
