#!/usr/bin/env python3
"""Golden-fixture generator: a bit-exact numpy mirror of the repo's host
FCM engines, used to produce the committed expected label bytes under
fixtures/expected/.

Why a mirror: the fixtures pin cross-PR output drift (tests/golden.rs
byte-compares every engine against them), so the expected bytes must be
derived from the engines' defined arithmetic, not from whatever binary
happened to be lying around. Every operation below reproduces the Rust
code's IEEE semantics exactly: f32 storage rounding (np.float32), f64
accumulators (python floats), the xoshiro256++ init stream, the fixed
per-slice partial grid + pairwise z-order tree reduction, the
lane-major fused sigma accumulation (pixel k of a chunk feeds logical
lane k % LANES; lane partials fold in fixed lane order at chunk end —
fcm::engine::fused's SIMD-era contract), and the m=2 / p=q=1 fast
paths (no libm powf anywhere on the default-parameter paths). On top
of bit-exactness, generation asserts wide safety margins
(distance to the ZERO_TOL singularity, to the epsilon convergence
boundary, and argmax label margins), so the committed labels are stable
far beyond last-ulp concerns.

Regeneration: python3 gen_fixtures.py   (from this directory)
A toolchain machine can instead re-bless from the Rust side with
REPRO_BLESS=1 cargo test --test golden  after verifying a change is an
intended output change.
"""

import os
import numpy as np

f32 = np.float32
M64 = (1 << 64) - 1
ZERO_TOL = 1e-12
DEN_EPS = 1e-12
# fused::LANES — the fixed logical accumulation lane count (a numerical
# constant shared by the scalar and AVX kernels, not a hardware width).
LANES = 4

HERE = os.path.dirname(os.path.abspath(__file__))

# ---------------------------------------------------------------- rng ----


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Rng64:
    """util::rng::Rng64 — xoshiro256++ seeded via splitmix64."""

    def __init__(self, seed):
        s = []
        sm = seed & M64
        for _ in range(4):
            sm = (sm + 0x9E3779B97F4A7C15) & M64
            z = sm
            z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
            z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
            s.append((z ^ (z >> 31)) & M64)
        self.s = s

    def next_u64(self):
        s = self.s
        r = (_rotl((s[0] + s[3]) & M64, 23) + s[0]) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def next_f32(self):
        # (next_u64() >> 40) as f32 * (1.0 / 2^24) — both factors exact.
        return f32(self.next_u64() >> 40) * f32(1.0 / 16777216.0)

    def uniform(self, lo, hi):
        lo = f32(lo)
        hi = f32(hi)
        return lo + (hi - lo) * self.next_f32()


def init_membership_masked(c, w, seed):
    """fcm::init_membership (+ masked zeroing). w: np.float32[n]."""
    n = len(w)
    u = np.zeros((c, n), dtype=np.float32)
    rng = Rng64(seed)
    for i in range(n):
        sm = f32(0.0)
        for j in range(c):
            v = rng.uniform(0.01, 1.0)
            u[j, i] = v
            sm = sm + v
        for j in range(c):
            u[j, i] = u[j, i] / sm
    for i in range(n):
        if w[i] == 0.0:
            for j in range(c):
                u[j, i] = f32(0.0)
    return u


# ---------------------------------------------------- margin tracking ----

MARGINS = {"min_d2": float("inf"), "min_eps_gap": float("inf"), "min_label_gap": float("inf")}


def track_d2(d2):
    if d2 < MARGINS["min_d2"]:
        MARGINS["min_d2"] = d2


def track_delta(delta, eps):
    gap = abs(float(delta) - float(f32(eps)))
    if gap < MARGINS["min_eps_gap"]:
        MARGINS["min_eps_gap"] = gap


def track_labels(u):
    # Margin between the winning and runner-up membership per column.
    a = np.sort(np.asarray(u, dtype=np.float64), axis=0)
    gap = float(np.min(a[-1, :] - a[-2, :]))
    if gap < MARGINS["min_label_gap"]:
        MARGINS["min_label_gap"] = gap


# --------------------------------------------------- shared primitives ----


def membership_row(xi, w_i, centers, c):
    """One pixel of sequential::update_memberships / fused_chunk (m=2):
    returns the list of new f32 memberships. xi: f64, centers: f32[]."""
    d2 = []
    nzero = 0
    for j in range(c):
        d = xi - float(centers[j])
        dd = d * d
        d2.append(dd)
        track_d2(dd)
        if dd <= ZERO_TOL:
            nzero += 1
    wi = f32(1.0) if w_i > 0.0 else f32(0.0)
    if nzero > 0:
        vals = []
        for j in range(c):
            vals.append(wi / f32(nzero) if d2[j] <= ZERO_TOL else f32(0.0))
        return vals, d2
    inv = []
    sum_inv = 0.0
    for j in range(c):
        inv.append(1.0 / d2[j])
        sum_inv += inv[j]
    vals = []
    for j in range(c):
        vals.append(f32(inv[j] / sum_inv) * wi)
    return vals, d2


def fold_lanes(num, den, jm, delta, c):
    """fused::LaneAcc::fold — collapse the per-lane f64 partials in
    fixed lane order 0..LANES (each sum a left fold from +0.0)."""
    out_num = []
    out_den = []
    for j in range(c):
        nj = 0.0
        dj = 0.0
        for l in range(LANES):
            nj += num[j][l]
            dj += den[j][l]
        out_num.append(nj)
        out_den.append(dj)
    jt = 0.0
    for l in range(LANES):
        jt += jm[l]
    return {"num": out_num, "den": out_den, "jm": jt, "delta": delta}


def fused_slice(x64, w, u_old, centers, u_new, start, length, c):
    """fused::fused_chunk over [start, start+length): lane-major sigma
    accumulation (pixel k -> lane k % LANES, serial f64 per lane, fixed
    lane-order fold at chunk end — identical for the scalar and AVX
    kernels). Writes u_new columns, returns PassPartial
    (num, den, jm, delta)."""
    num = [[0.0] * LANES for _ in range(c)]
    den = [[0.0] * LANES for _ in range(c)]
    jm = [0.0] * LANES
    delta = f32(0.0)
    for k in range(length):
        i = start + k
        lane = k % LANES
        vals, d2 = membership_row(x64[i], w[i], centers, c)
        for j in range(c):
            val = vals[j]
            diff = abs(val - u_old[j, i])
            if diff > delta:
                delta = diff
            u_new[j, i] = val
            vf = float(val)
            um = vf * vf
            wu = float(w[i]) * um
            num[j][lane] += wu * x64[i]
            den[j][lane] += wu
            jm[lane] += wu * d2[j]
    return fold_lanes(num, den, jm, delta, c)


def centers_slice(x64, w, u, start, length, c):
    """fused::centers_chunk: sigma sums of an existing membership chunk."""
    num = [0.0] * c
    den = [0.0] * c
    for j in range(c):
        for k in range(length):
            i = start + k
            wu = float(w[i]) * float(u[j, i]) * float(u[j, i])
            num[j] += wu * x64[i]
            den[j] += wu
    return {"num": num, "den": den, "jm": 0.0, "delta": f32(0.0)}


def combine(a, b):
    return {
        "num": [p + q for p, q in zip(a["num"], b["num"])],
        "den": [p + q for p, q in zip(a["den"], b["den"])],
        "jm": a["jm"] + b["jm"],
        "delta": max(a["delta"], b["delta"]),
    }


def tree_reduce(items):
    level = list(items)
    while len(level) > 1:
        nxt = []
        for i in range(0, len(level), 2):
            if i + 1 < len(level):
                nxt.append(combine(level[i], level[i + 1]))
            else:
                nxt.append(level[i])
        level = nxt
    return level[0]


def part_centers(part, c):
    return np.array(
        [f32(part["num"][j] / max(part["den"][j], DEN_EPS)) for j in range(c)],
        dtype=np.float32,
    )


def defuzzify(u, c, n):
    labels = np.zeros(n, dtype=np.uint8)
    for i in range(n):
        best = 0
        best_v = u[0, i]
        for j in range(1, c):
            if u[j, i] > best_v:
                best_v = u[j, i]
                best = j
        labels[i] = best
    return labels


def canonical_rank(centers):
    """fcm::canonical_order: stable ascending sort; rank[old] = new."""
    order = sorted(range(len(centers)), key=lambda j: float(centers[j]))
    rank = [0] * len(centers)
    for new, old in enumerate(order):
        rank[old] = new
    return order, rank


def canonical_labels(labels, centers, w):
    _, rank = canonical_rank(centers)
    out = np.zeros(len(labels), dtype=np.uint8)
    for i, l in enumerate(labels):
        out[i] = rank[l] if w[i] > 0.0 else 0
    return out


# ----------------------------------------------------------- engines ----


def run_parallel_volume(vox, w, area, params, require_converged=True):
    """engine::volume::run_volume, Backend::Parallel (the slab path):
    per-slice fused partials, pairwise z-order tree. Returns the final
    (u, centers) and run metadata; labels via the caller.
    `require_converged=False` allows capped runs (the verification
    mirrors exercise the skip-update-on-last-iteration semantics; the
    committed fixtures always converge)."""
    c, eps, max_iters, seed = params["c"], params["eps"], params["max_iters"], params["seed"]
    n = len(vox)
    x64 = [float(v) for v in vox]
    u = init_membership_masked(c, w, seed)
    slices = [(s, area) for s in range(0, n, area)]
    parts = [centers_slice(x64, w, u, s, l, c) for s, l in slices]
    centers = part_centers(tree_reduce(parts), c)
    u_new = np.zeros_like(u)
    jm_history = []
    converged = False
    iterations = 0
    for it in range(max_iters):
        iterations += 1
        parts = [fused_slice(x64, w, u, centers, u_new, s, l, c) for s, l in slices]
        total = tree_reduce(parts)
        u, u_new = u_new, u
        jm_history.append(total["jm"])
        track_delta(total["delta"], eps)
        if total["delta"] < f32(eps):
            converged = True
            break
        if it + 1 < max_iters:
            centers = part_centers(total, c)
    assert converged or not require_converged, "parallel volume mirror did not converge"
    return u, centers, iterations, jm_history


def run_sequential(x_vals, w, params):
    """fcm::sequential::run (the per-slice baseline): linear f64 sums."""
    c, eps, max_iters, seed = params["c"], params["eps"], params["max_iters"], params["seed"]
    n = len(x_vals)
    x64 = [float(v) for v in x_vals]
    u = init_membership_masked(c, w, seed)
    u_new = np.zeros_like(u)
    centers = np.zeros(c, dtype=np.float32)
    converged = False
    for _ in range(max_iters):
        update_centers(x64, w, u, centers, c)
        delta = f32(0.0)
        for i in range(n):
            vals, _ = membership_row(x64[i], w[i], centers, c)
            for j in range(c):
                diff = abs(vals[j] - u[j, i])
                if diff > delta:
                    delta = diff
                u_new[j, i] = vals[j]
        u, u_new = u_new, u
        track_delta(delta, eps)
        if delta < f32(eps):
            converged = True
            break
    assert converged, "sequential mirror did not converge"
    return u, centers


def update_centers(x64, w, u, centers, c):
    """sequential::update_centers (m=2 branch), in place."""
    n = len(x64)
    for j in range(c):
        num = 0.0
        den = 0.0
        for i in range(n):
            wum = float(w[i]) * float(u[j, i]) * float(u[j, i])
            num += wum * x64[i]
            den += wum
        centers[j] = f32(num / max(den, DEN_EPS))


def fused_bins(xb64, wb, u_bin, centers, u_new, occupied, c):
    """fused::fused_chunk over one whole bin axis (the bin_iterations
    call: start 0, length = levels), restricted to occupied bins. Bin b
    keeps lane slot b % LANES — its chunk position — and every empty
    bin is an exact no-op (wi = 0 makes its stored value +0.0, its
    delta 0, and its wu terms +0.0, which add exactly nothing to the
    non-negative lane accumulators), so skipping them is bit-neutral.
    This is what makes the 65 536-bin mirror tractable in Python."""
    num = [[0.0] * LANES for _ in range(c)]
    den = [[0.0] * LANES for _ in range(c)]
    jm = [0.0] * LANES
    delta = f32(0.0)
    for b in occupied:
        lane = b % LANES
        vals, d2 = membership_row(xb64[b], wb[b], centers, c)
        for j in range(c):
            val = vals[j]
            diff = abs(val - u_bin[j, b])
            if diff > delta:
                delta = diff
            u_new[j, b] = val
            vf = float(val)
            um = vf * vf
            wu = float(wb[b]) * um
            num[j][lane] += wu * xb64[b]
            den[j][lane] += wu
            jm[lane] += wu * d2[j]
    return fold_lanes(num, den, jm, delta, c)


def run_histogram_volume(vox, w, area, params, levels=256):
    """engine::volume::run_histogram (and its streamed twin): exact
    integer counts, centers_1 from the full voxel-level u_0, bin-level
    iterations. `levels` is 256 for 8-bit rasters, 65536 for the 16-bit
    RVOL path (engine::stream::hist_streamed sizes bins from
    VoxelSource::sample_bits)."""
    c, eps, max_iters, seed = params["c"], params["eps"], params["max_iters"], params["seed"]
    n = len(vox)
    x64 = [float(v) for v in vox]
    u0 = init_membership_masked(c, w, seed)
    counts = [0] * levels
    for i, v in enumerate(vox):
        if w[i] > 0.0:
            counts[v] += 1
    occ = [b for b in range(levels) if counts[b] > 0]
    xb64 = [float(b) for b in range(levels)]
    wb = np.array([f32(cnt) for cnt in counts], dtype=np.float32)
    slices = [(s, area) for s in range(0, n, area)]
    parts = [centers_slice(x64, w, u0, s, l, c) for s, l in slices]
    centers = part_centers(tree_reduce(parts), c)
    u_bin = np.zeros((c, levels), dtype=np.float32)
    for j in range(c):
        sums = [0.0] * levels
        for i, v in enumerate(vox):
            sums[v] += float(u0[j, i])
        for b in occ:
            u_bin[j, b] = f32(sums[b] / counts[b])
    u_new = np.zeros_like(u_bin)
    converged = False
    for it in range(max_iters):
        part = fused_bins(xb64, wb, u_bin, centers, u_new, occ, c)
        u_bin, u_new = u_new, u_bin
        track_delta(part["delta"], eps)
        if part["delta"] < f32(eps):
            converged = True
            break
        if it + 1 < max_iters:
            centers = part_centers(part, c)
    assert converged, "histogram mirror did not converge"
    bin_labels = defuzzify(u_bin, c, levels)
    _, rank = canonical_rank(centers)
    labels = np.zeros(n, dtype=np.uint8)
    for i, v in enumerate(vox):
        labels[i] = rank[bin_labels[v]] if w[i] > 0.0 else 0
    # Label margins at bin level, occupied bins only.
    track_labels(u_bin[:, occ])
    return labels


def box3d(u, gw, gh, d, c, radius=1):
    """spatial::spatial_function_3d: separable three-pass f32 box sum."""
    area = gw * gh
    n = area * d
    out = np.zeros_like(u)
    tmp1 = np.zeros(n, dtype=np.float32)
    tmp2 = np.zeros(n, dtype=np.float32)
    for j in range(c):
        row = u[j]
        for z in range(d):
            for r in range(gh):
                base = z * area + r * gw
                for col in range(gw):
                    lo = max(col - radius, 0)
                    hi = min(col + radius, gw - 1)
                    acc = f32(0.0)
                    for cc in range(lo, hi + 1):
                        acc = acc + row[base + cc]
                    tmp1[base + col] = acc
        for z in range(d):
            for r in range(gh):
                lo = max(r - radius, 0)
                hi = min(r + radius, gh - 1)
                for col in range(gw):
                    acc = f32(0.0)
                    for rr in range(lo, hi + 1):
                        acc = acc + tmp1[z * area + rr * gw + col]
                    tmp2[z * area + r * gw + col] = acc
        for z in range(d):
            lo = max(z - radius, 0)
            hi = min(z + radius, d - 1)
            for i in range(area):
                acc = f32(0.0)
                for zz in range(lo, hi + 1):
                    acc = acc + tmp2[zz * area + i]
                out[j, z * area + i] = acc
    return out


def run_spatial_volume(vox, w, gw, gh, d, params):
    """spatial::run_volume with default SpatialParams (p=q=1, r=1):
    parallel phase 1, then modulated iterations (pw fast path: p=q=1 is
    the identity — no powf)."""
    c, eps, max_iters = params["c"], params["eps"], params["max_iters"]
    area = gw * gh
    n = len(vox)
    x64 = [float(v) for v in vox]
    u, centers, _, _ = run_parallel_volume(vox, w, area, params)
    centers = np.array(centers, dtype=np.float32, copy=True)
    u_new = np.zeros_like(u)
    converged = False
    for _ in range(max_iters):
        update_centers(x64, w, u, centers, c)
        for i in range(n):
            vals, _ = membership_row(x64[i], w[i], centers, c)
            for j in range(c):
                u_new[j, i] = vals[j]
        h = box3d(u_new, gw, gh, d, c)
        delta = f32(0.0)
        for i in range(n):
            sm = f32(0.0)
            for j in range(c):
                v = u_new[j, i] * h[j, i]
                u_new[j, i] = v
                sm = sm + v
            if sm > 0.0:
                for j in range(c):
                    u_new[j, i] = u_new[j, i] / sm
            for j in range(c):
                diff = abs(u_new[j, i] - u[j, i])
                if diff > delta:
                    delta = diff
        u, u_new = u_new, u
        track_delta(delta, eps)
        if delta < f32(eps):
            converged = True
            break
    assert converged, "spatial mirror did not converge"
    return u, centers


# --------------------------------------------------- fixture dataset ----


def fixture_volume(gw, gh, d):
    """Four well-separated intensity bands in a deterministic spatial
    pattern, with deterministic jitter so no center can collide with a
    voxel value (ZERO_TOL margin) and argmax margins stay wide."""
    base = [20, 90, 160, 230]
    vox = []
    for z in range(d):
        for y in range(gh):
            for x in range(gw):
                cls = ((x // 2) + (y // 2) + z) % 4
                jit = (3 * x + 5 * y + 7 * z) % 5
                vox.append(base[cls] + jit)
    return vox


def fixture_volume16(gw, gh, d):
    """16-bit sibling of fixture_volume: four bands deep in the u16
    range (gaps ~15k >> jitter <900) so every engine lands on the same
    labels and all margin gates hold with room to spare."""
    base = [5000, 21000, 40000, 58000]
    vox = []
    for z in range(d):
        for y in range(gh):
            for x in range(gw):
                cls = ((x // 2) + (y // 2) + z) % 4
                jit = (311 * x + 521 * y + 737 * z) % 900
                vox.append(base[cls] + jit)
    return vox


def fixture_mask(gw, gh, d):
    mask = []
    for z in range(d):
        for y in range(gh):
            for x in range(gw):
                mask.append(0 if (x + y + z) % 7 == 0 else 1)
    return mask


def weights(mask):
    return np.array([f32(1.0) if m > 0 else f32(0.0) for m in mask], dtype=np.float32)


def slice_loop_sequential(vox, mask, gw, gh, d, params):
    """SequentialBackend::segment_volume — the default per-slice batch
    flatten: one independent sequential run per axial slice, each
    canonicalized (finish_host_run), stitched in z order."""
    area = gw * gh
    labels = np.zeros(len(vox), dtype=np.uint8)
    for z in range(d):
        xs = vox[z * area:(z + 1) * area]
        w = weights(mask[z * area:(z + 1) * area])
        u, centers = run_sequential([f32(v) for v in xs], w, params)
        track_labels(u[:, w > 0])
        raw = defuzzify(u, params["c"], area)
        labels[z * area:(z + 1) * area] = canonical_labels(raw, centers, w)
    return labels


def volume_labels(run_fn, vox, mask, gw, gh, d, params):
    area = gw * gh
    w = weights(mask)
    if run_fn is run_spatial_volume:
        u, centers = run_spatial_volume(vox, w, gw, gh, d, params)
    else:
        u, centers, _, _ = run_parallel_volume(vox, w, area, params)
    track_labels(u[:, w > 0])
    raw = defuzzify(u, params["c"], len(vox))
    return canonical_labels(raw, centers, w)


# ------------------------------------------------------------ writers ----


def write_rvol(path, gw, gh, d, data):
    with open(path, "wb") as f:
        f.write(f"RVOL\n{gw} {gh} {d}\n255\n".encode())
        f.write(bytes(data))


def write_rvol16(path, gw, gh, d, data):
    """16-bit RVOL: maxval 65535, big-endian two-byte raster samples
    (image::volume::save_raw_u16 / RvolReader's streaming-only path)."""
    with open(path, "wb") as f:
        f.write(f"RVOL\n{gw} {gh} {d}\n65535\n".encode())
        f.write(b"".join(int(v).to_bytes(2, "big") for v in data))


def write_pgm(path, gw, gh, data):
    with open(path, "wb") as f:
        f.write(f"P5\n{gw} {gh}\n255\n".encode())
        f.write(bytes(data))


def write_labels(name, labels):
    path = os.path.join(HERE, "expected", name)
    with open(path, "wb") as f:
        f.write(bytes(int(l) for l in labels))
    print(f"  {name}: {len(labels)} bytes, counts {np.bincount(labels, minlength=4).tolist()}")


def main():
    gw, gh, d = 8, 8, 6
    params = {"c": 4, "eps": 0.005, "max_iters": 300, "seed": 42}
    vox = fixture_volume(gw, gh, d)
    mask = fixture_mask(gw, gh, d)
    all_real = [1] * len(vox)
    os.makedirs(os.path.join(HERE, "expected"), exist_ok=True)
    os.makedirs(os.path.join(HERE, "stack"), exist_ok=True)

    write_rvol(os.path.join(HERE, "vol.rvol"), gw, gh, d, vox)
    write_rvol(os.path.join(HERE, "mask.rvol"), gw, gh, d, mask)
    area = gw * gh
    for z in range(3):
        write_pgm(
            os.path.join(HERE, "stack", f"slice_{z:04}.pgm"),
            gw,
            gh,
            vox[z * area:(z + 1) * area],
        )

    print("unmasked volume:")
    write_labels("sequential.labels", slice_loop_sequential(vox, all_real, gw, gh, d, params))
    write_labels("parallel.labels", volume_labels(run_parallel_volume, vox, all_real, gw, gh, d, params))
    write_labels("histogram.labels", run_histogram_volume(vox, weights(all_real), area, params))
    write_labels("spatial.labels", volume_labels(run_spatial_volume, vox, all_real, gw, gh, d, params))

    print("masked volume:")
    write_labels("sequential_masked.labels", slice_loop_sequential(vox, mask, gw, gh, d, params))
    write_labels("parallel_masked.labels", volume_labels(run_parallel_volume, vox, mask, gw, gh, d, params))
    write_labels("histogram_masked.labels", run_histogram_volume(vox, weights(mask), area, params))
    write_labels("spatial_masked.labels", volume_labels(run_spatial_volume, vox, mask, gw, gh, d, params))

    print("3-slice PGM stack:")
    stack_vox = vox[: 3 * area]
    write_labels(
        "stack_parallel.labels",
        volume_labels(run_parallel_volume, stack_vox, [1] * len(stack_vox), gw, gh, 3, params),
    )

    print("16-bit volume (streaming-only engines):")
    vox16 = fixture_volume16(gw, gh, d)
    write_rvol16(os.path.join(HERE, "vol16.rvol"), gw, gh, d, vox16)
    p16 = volume_labels(run_parallel_volume, vox16, all_real, gw, gh, d, params)
    h16 = run_histogram_volume(vox16, weights(all_real), area, params, levels=65536)
    write_labels("parallel_u16.labels", p16)
    write_labels("histogram_u16.labels", h16)
    # The wide-bin histogram engine must land on the slab engine's
    # segmentation (a streaming.rs gate on this same fixture).
    assert np.array_equal(p16, h16), "u16 histogram labels diverge from the slab engine"

    print(f"margins: {MARGINS}")
    # The singularity branch triggers at d2 <= 1e-12, i.e. |d| <= 1e-6.
    # Requiring min d2 > 1e-9 keeps every trajectory distance at least
    # 30x above that |d| threshold — far beyond any last-ulp wobble.
    assert MARGINS["min_d2"] > 1e-9, "trajectory too close to the ZERO_TOL singularity"
    assert MARGINS["min_eps_gap"] > 1e-4, "a delta too close to the epsilon boundary"
    assert MARGINS["min_label_gap"] > 0.05, "an argmax label margin too thin"
    print("all margin gates passed")


if __name__ == "__main__":
    main()
