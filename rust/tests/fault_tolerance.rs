//! Integration suite for the fault-tolerance layer (PR 6):
//!
//! * cooperative cancellation is observed within ONE tile of the
//!   deadline firing — the tile-granularity contract of DESIGN.md;
//! * a panicking job comes back as the typed [`JobFailed`] and the
//!   worker pool survives to serve the next job;
//! * transient I/O faults heal through the retry loop with output
//!   **byte-identical** to a first-try run (engines are deterministic,
//!   so re-running is safe);
//! * deadline and explicit-cancel jobs land in the `cancelled` counter,
//!   over-budget submissions in `rejected` — never in `failed`;
//! * the soak gate: 64 concurrent mixed jobs (good / healing-fault /
//!   permanent-fault / cancelled, plus over-budget rejections) drain
//!   cleanly with EXACT metrics accounting and zero admission bytes
//!   left in flight.

mod common;

use repro::config::Config;
use repro::coordinator::{
    backend_for, CancelToken, Engine, Interrupted, JobFailed, Rejected, Service, StreamVolumeJob,
    Ticket,
};
use repro::fcm::engine::stream::{estimated_peak_resident_bytes, StreamOpts};
use repro::fcm::{Backend, EngineOpts, FcmParams};
use repro::image::volume::stream::{FaultPlan, FaultySource, RvolReader};
use repro::image::{volume, VoxelVolume};
use repro::phantom::{generate_volume, PhantomConfig};
use std::path::PathBuf;
use std::time::Duration;

fn phantom_rvol(width: usize, height: usize, depth: usize) -> VoxelVolume {
    let start = 90usize.min(181 - depth);
    generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
    .to_voxel_volume()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fault_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Fixed-iteration params: epsilon unreachable, so every run does the
/// same work and finishes fast — and byte-identity across runs is a
/// pure determinism check, not a convergence coincidence.
fn fast_params() -> FcmParams {
    FcmParams {
        epsilon: 0.0,
        max_iters: 6,
        ..FcmParams::default()
    }
}

#[test]
fn cancellation_is_observed_within_one_tile() {
    // 25 ms of injected latency per tile read and a 60 ms deadline: the
    // token fires a couple of reads in, and the engine must abort at
    // the next between-tile checkpoint — nowhere near the dozens of
    // reads a full multi-iteration sweep performs.
    let dir = tmp_dir("cancel_tile");
    let vol = phantom_rvol(31, 29, 12);
    let path = dir.join("v.rvol");
    volume::save_raw(&vol, &path).unwrap();
    let plan = FaultPlan {
        latency: Duration::from_millis(25),
        ..FaultPlan::default()
    };
    let mut src = FaultySource::new(Box::new(RvolReader::open(&path).unwrap()), plan, 0);
    let mut sink = Vec::new();
    let cancel = CancelToken::with_timeout(Duration::from_millis(60));
    let backend = backend_for(Engine::Parallel, None, &EngineOpts::default()).unwrap();
    let err = backend
        .segment_volume_streamed_cancellable(&mut src, &mut sink, &fast_params(), 2, &cancel)
        .unwrap_err();
    assert!(
        matches!(err.downcast_ref::<Interrupted>(), Some(Interrupted::DeadlineExceeded)),
        "expected the typed deadline error, got: {err:#}"
    );
    // Depth 12 at tile 2 is 6 reads per sweep; a capped run does 8
    // sweeps. Tile-granular cancellation stops within the first.
    assert!(
        src.reads() <= 6,
        "cancel took {} reads to observe — not tile-granular",
        src.reads()
    );
    assert!(sink.is_empty(), "no labels may stream after cancellation");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn worker_panic_becomes_typed_job_failed_and_pool_survives() {
    let dir = tmp_dir("panic");
    let vol = phantom_rvol(17, 19, 6);
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    let service = Service::start(&cfg).unwrap();

    let bomb = StreamVolumeJob {
        input: input.clone(),
        mask: None,
        output: dir.join("bomb.rvol"),
        tile_slices: 2,
        prefetch: false,
        fault: Some(FaultPlan {
            fail_on_read: 1,
            fail_attempts: u32::MAX,
            panic_on_read: true,
            ..FaultPlan::default()
        }),
    };
    let err = service
        .submit_volume_streamed(bomb, fast_params(), Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap_err();
    let failed = err
        .downcast_ref::<JobFailed>()
        .expect("a panicking job must surface as the typed JobFailed");
    assert_eq!(failed.worker, 0);
    assert!(
        failed.reason.contains("injected fault"),
        "panic payload lost: {}",
        failed.reason
    );
    assert!(!dir.join("bomb.rvol").exists());
    assert!(!dir.join("bomb.rvol.tmp").exists());

    // The sole worker must still be alive to serve the next job.
    let good = StreamVolumeJob {
        input,
        mask: None,
        output: dir.join("good.rvol"),
        tile_slices: 2,
        prefetch: false,
        fault: None,
    };
    let r = service
        .submit_volume_streamed(good, fast_params(), Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.worker, 0, "the panicked worker must serve again");
    let snap = service.shutdown();
    assert_eq!(snap.submitted, 2);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.cancelled, 0);
    assert_eq!(snap.retried, 0, "a panic is not a transient fault");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn transient_fault_heals_with_byte_identical_output() {
    let dir = tmp_dir("retry");
    let vol = phantom_rvol(21, 23, 8);
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    cfg.service.retry_backoff_ms = 1;
    let service = Service::start(&cfg).unwrap();
    let spec = |out: PathBuf, fault: Option<FaultPlan>| StreamVolumeJob {
        input: input.clone(),
        mask: None,
        output: out,
        tile_slices: 2,
        prefetch: false,
        fault,
    };

    let clean_out = dir.join("clean.rvol");
    service
        .submit_volume_streamed(spec(clean_out.clone(), None), fast_params(), Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    // Armed for attempt 0 only: the second read of the first attempt
    // fails, the retry reads clean and must reproduce the run exactly.
    let healed_out = dir.join("healed.rvol");
    let r = service
        .submit_volume_streamed(
            spec(
                healed_out.clone(),
                Some(FaultPlan {
                    fail_on_read: 2,
                    fail_attempts: 1,
                    ..FaultPlan::default()
                }),
            ),
            fast_params(),
            Engine::Parallel,
        )
        .unwrap()
        .wait()
        .unwrap();
    assert!(r.peak_resident_bytes.is_some());
    let snap = service.shutdown();
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.retried, 1, "exactly one retry attempt");
    assert_eq!(
        std::fs::read(&clean_out).unwrap(),
        std::fs::read(&healed_out).unwrap(),
        "retried output must be byte-identical to the first-try run"
    );
    assert!(!dir.join("healed.rvol.tmp").exists(), "no .tmp debris");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn job_timeout_deadline_counts_as_cancelled() {
    let dir = tmp_dir("deadline");
    let vol = phantom_rvol(17, 19, 12);
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    cfg.service.job_timeout_ms = 80;
    let service = Service::start(&cfg).unwrap();
    let slow = StreamVolumeJob {
        input,
        mask: None,
        output: dir.join("never.rvol"),
        tile_slices: 1,
        prefetch: false,
        // 20 ms per read, 12 reads per sweep: the deadline fires during
        // the first sweep and the run aborts between tiles.
        fault: Some(FaultPlan {
            latency: Duration::from_millis(20),
            ..FaultPlan::default()
        }),
    };
    let err = service
        .submit_volume_streamed(slow, fast_params(), Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap_err();
    assert!(
        matches!(err.downcast_ref::<Interrupted>(), Some(Interrupted::DeadlineExceeded)),
        "expected the typed deadline error, got: {err:#}"
    );
    assert!(!dir.join("never.rvol").exists());
    let snap = service.shutdown();
    assert_eq!(snap.cancelled, 1, "deadlines count as cancelled");
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 0);
    assert_eq!(snap.retried, 0, "an interrupted job is never retried");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn explicit_cancel_fast_fails_queued_jobs() {
    let dir = tmp_dir("cancel_queue");
    let vol = phantom_rvol(17, 19, 8);
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    let service = Service::start(&cfg).unwrap();
    let spec = |out: &str, fault: Option<FaultPlan>| StreamVolumeJob {
        input: input.clone(),
        mask: None,
        output: dir.join(out),
        tile_slices: 2,
        prefetch: false,
        fault,
    };
    // A slow blocker holds the sole worker while the jobs under test
    // sit in the queue.
    let blocker = service
        .submit_volume_streamed(
            spec(
                "blocker.rvol",
                Some(FaultPlan {
                    latency: Duration::from_millis(10),
                    ..FaultPlan::default()
                }),
            ),
            fast_params(),
            Engine::Parallel,
        )
        .unwrap();
    let queued: Vec<Ticket> = (0..3)
        .map(|i| {
            let t = service
                .submit_volume_streamed(
                    spec(&format!("queued{i}.rvol"), None),
                    fast_params(),
                    Engine::Parallel,
                )
                .unwrap();
            t.cancel();
            t
        })
        .collect();
    for t in queued {
        let err = t.wait().unwrap_err();
        assert!(
            matches!(err.downcast_ref::<Interrupted>(), Some(Interrupted::Cancelled)),
            "expected the typed cancel error, got: {err:#}"
        );
    }
    blocker.wait().unwrap();
    let snap = service.shutdown();
    assert_eq!(snap.submitted, 4);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.cancelled, 3);
    assert_eq!(snap.failed, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn over_budget_submission_is_rejected_with_typed_error() {
    let dir = tmp_dir("reject");
    let vol = phantom_rvol(45, 53, 16);
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let params = fast_params();
    let big_est = estimated_peak_resident_bytes(
        45 * 53,
        16,
        params.clusters,
        &StreamOpts {
            backend: Backend::Parallel,
            threads: 0,
            tile_slices: 16,
        },
    );
    let small_est = estimated_peak_resident_bytes(
        45 * 53,
        16,
        params.clusters,
        &StreamOpts {
            backend: Backend::Parallel,
            threads: 0,
            tile_slices: 2,
        },
    );
    assert!(small_est < big_est);
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    cfg.service.resident_budget_bytes = big_est - 1;
    let service = Service::start(&cfg).unwrap();
    let spec = |out: &str, tile_slices: usize| StreamVolumeJob {
        input: input.clone(),
        mask: None,
        output: dir.join(out),
        tile_slices,
        prefetch: false,
        fault: None,
    };

    // Larger than the budget can EVER accommodate: rejected instantly,
    // without the bounded wait.
    let err = service
        .submit_volume_streamed(spec("big.rvol", 16), params, Engine::Parallel)
        .unwrap_err();
    let rejected = err
        .downcast_ref::<Rejected>()
        .expect("over-budget submission must surface the typed Rejected");
    assert_eq!(rejected.would_exceed, big_est);
    assert_eq!(rejected.budget, big_est - 1);

    // The small job fits and completes; its measured peak IS the
    // estimate the admission controller charged it for.
    let r = service
        .submit_volume_streamed(spec("small.rvol", 2), params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.peak_resident_bytes, Some(small_est));
    let snap = service.shutdown();
    assert_eq!(snap.rejected, 1);
    assert_eq!(snap.submitted, 1, "rejected jobs are never counted submitted");
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.failed, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn soak_mixed_jobs_drain_with_exact_accounting() {
    // THE robustness gate: 64 concurrent jobs — 40 good, 8 with a
    // healing transient fault (exactly one retry each), 8 with a
    // permanent fault (retries exhaust), 8 cancelled at submit — plus 4
    // over-budget rejections, against 8 workers.
    // Everything drains, every counter lands exactly, healed outputs
    // are byte-identical to a clean run's, and the admission controller
    // ends with zero bytes in flight.
    let dir = tmp_dir("soak");
    let params = fast_params();
    let small = phantom_rvol(17, 19, 6);
    let small_path = dir.join("small.rvol");
    volume::save_raw(&small, &small_path).unwrap();
    let big = phantom_rvol(128, 128, 16);
    let big_path = dir.join("big.rvol");
    volume::save_raw(&big, &big_path).unwrap();

    let est = |backend: Backend, area: usize, depth: usize, tile: usize| {
        estimated_peak_resident_bytes(
            area,
            depth,
            params.clusters,
            &StreamOpts {
                backend,
                threads: 0,
                tile_slices: tile,
            },
        )
    };
    let par_est = est(Backend::Parallel, 17 * 19, 6, 2);
    let hist_est = est(Backend::Histogram, 17 * 19, 6, 2);
    let big_est = est(Backend::Parallel, 128 * 128, 16, 16);
    // Budget: every admitted job can hold its permit at once, but the
    // big job must still overshoot — instant typed rejection.
    let budget = 64 * par_est.max(hist_est);
    assert!(budget < big_est, "soak geometry: {budget} vs {big_est}");

    let mut cfg = Config::new();
    cfg.service.workers = 8;
    cfg.service.queue_depth = 128;
    cfg.service.max_retries = 2;
    cfg.service.retry_backoff_ms = 1;
    cfg.service.resident_budget_bytes = budget;
    cfg.engine.threads = common::engine_threads();
    let service = Service::start(&cfg).unwrap();
    let admission = service.admission().clone();
    let spec = |out: String, fault: Option<FaultPlan>| StreamVolumeJob {
        input: small_path.clone(),
        mask: None,
        output: dir.join(out),
        tile_slices: 2,
        prefetch: false,
        fault,
    };

    let good: Vec<(usize, Ticket)> = (0..40)
        .map(|i| {
            let engine = if i % 2 == 0 { Engine::Parallel } else { Engine::Histogram };
            let t = service
                .submit_volume_streamed(spec(format!("good{i}.rvol"), None), params, engine)
                .unwrap();
            (i, t)
        })
        .collect();
    let healing: Vec<(usize, Ticket)> = (0..8)
        .map(|i| {
            let t = service
                .submit_volume_streamed(
                    spec(
                        format!("heal{i}.rvol"),
                        Some(FaultPlan {
                            fail_on_read: 1 + i % 3,
                            fail_attempts: 1,
                            ..FaultPlan::default()
                        }),
                    ),
                    params,
                    Engine::Parallel,
                )
                .unwrap();
            (i, t)
        })
        .collect();
    let doomed: Vec<(usize, Ticket)> = (0..8)
        .map(|i| {
            let t = service
                .submit_volume_streamed(
                    spec(
                        format!("doom{i}.rvol"),
                        Some(FaultPlan {
                            fail_on_read: 1,
                            fail_attempts: u32::MAX,
                            ..FaultPlan::default()
                        }),
                    ),
                    params,
                    Engine::Parallel,
                )
                .unwrap();
            (i, t)
        })
        .collect();
    let cancelled: Vec<(usize, Ticket)> = (0..8)
        .map(|i| {
            let t = service
                .submit_volume_streamed(
                    spec(
                        format!("cancel{i}.rvol"),
                        Some(FaultPlan {
                            latency: Duration::from_millis(10),
                            ..FaultPlan::default()
                        }),
                    ),
                    params,
                    Engine::Parallel,
                )
                .unwrap();
            t.cancel();
            (i, t)
        })
        .collect();
    for i in 0..4 {
        let err = service
            .submit_volume_streamed(
                StreamVolumeJob {
                    input: big_path.clone(),
                    mask: None,
                    output: dir.join(format!("big{i}.rvol")),
                    tile_slices: 16,
                    prefetch: false,
                    fault: None,
                },
                params,
                Engine::Parallel,
            )
            .unwrap_err();
        assert!(
            err.downcast_ref::<Rejected>().is_some(),
            "over-budget job {i} must be the typed Rejected, got: {err:#}"
        );
    }

    // Drain. Good jobs succeed and report exactly the estimated peak
    // (the quantity their admission charged).
    for (i, t) in good {
        let r = t.wait().unwrap_or_else(|e| panic!("good job {i}: {e:#}"));
        let want = if i % 2 == 0 { par_est } else { hist_est };
        assert_eq!(r.peak_resident_bytes, Some(want), "good job {i}");
    }
    for (i, t) in healing {
        t.wait().unwrap_or_else(|e| panic!("healing job {i}: {e:#}"));
    }
    for (i, t) in doomed {
        let err = t.wait().expect_err("permanent fault must exhaust retries");
        assert!(
            err.downcast_ref::<Interrupted>().is_none(),
            "doomed job {i} must fail with the I/O error, not cancellation: {err:#}"
        );
        assert!(!dir.join(format!("doom{i}.rvol")).exists());
        assert!(!dir.join(format!("doom{i}.rvol.tmp")).exists());
    }
    for (i, t) in cancelled {
        let err = t.wait().expect_err("cancelled job must not complete");
        assert!(
            matches!(err.downcast_ref::<Interrupted>(), Some(Interrupted::Cancelled)),
            "cancelled job {i}: {err:#}"
        );
        assert!(!dir.join(format!("cancel{i}.rvol")).exists());
    }

    // Byte-identity: every healed output equals the clean Parallel run.
    let reference = std::fs::read(dir.join("good0.rvol")).unwrap();
    for i in 0..8 {
        assert_eq!(
            std::fs::read(dir.join(format!("heal{i}.rvol"))).unwrap(),
            reference,
            "healed job {i} diverged from the first-try run"
        );
    }

    let snap = service.shutdown();
    assert_eq!(snap.submitted, 64);
    assert_eq!(snap.completed, 48);
    assert_eq!(snap.failed, 8);
    assert_eq!(snap.cancelled, 8);
    assert_eq!(snap.rejected, 4);
    // 8 healing jobs x 1 retry + 8 permanent jobs x max_retries.
    assert_eq!(snap.retried, 8 + 16);
    assert_eq!(snap.submitted, snap.completed + snap.failed + snap.cancelled);
    assert_eq!(snap.streamed_runs, 48);
    assert_eq!(admission.in_flight(), 0, "drained service holds no admission bytes");
    assert!(admission.peak() > 0);
    assert!(admission.peak() <= budget, "admission never oversubscribed");
    std::fs::remove_dir_all(&dir).unwrap();
}
