//! Observability integration suite: histogram exactness contracts, the
//! exporter-vs-samples acceptance gate, result-neutrality of the engine
//! profiler, and the end-to-end job trace lifecycle.
//!
//! The central contract under test: every percentile the exporters
//! publish equals `bucket_floor(true order statistic)` of the exact
//! sample stream that was recorded — quantiles are sample-exact up to
//! bucketization, never estimated.

mod common;

use repro::coordinator::{backend_for, Engine, Metrics, Service};
use repro::fcm::{EngineOpts, FcmParams};
use repro::image::FeatureVector;
use repro::obs::hist::{bucket_floor, LatencyHist};
use repro::obs::{prof, Json, Stage};
use repro::phantom::{generate_slice, PhantomConfig};
use std::time::Duration;

/// Deterministic pseudo-random u64 stream (no rand crate offline).
struct Lcg(u64);

impl Lcg {
    fn step(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(6364136223846793005);
        self.0 = self.0.wrapping_add(1442695040888963407);
        self.0
    }

    /// A latency-shaped sample: mixes ns, µs, ms, and s magnitudes.
    fn sample(&mut self) -> u64 {
        let r = self.step();
        let magnitude = [1u64, 1_000, 1_000_000, 1_000_000_000][(r % 4) as usize];
        magnitude + self.step() % (magnitude * 9)
    }
}

/// The reference quantile the histogram contract promises: bucket floor
/// of the rank-`clamp(ceil(q*n),1,n)` order statistic.
fn reference_quantile(sorted: &[u64], q: f64) -> u64 {
    let n = sorted.len() as u64;
    let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
    bucket_floor(sorted[(rank - 1) as usize])
}

#[test]
fn quantiles_are_exact_order_statistics_up_to_bucketization() {
    let mut rng = Lcg(7);
    let samples: Vec<u64> = (0..5000).map(|_| rng.sample()).collect();
    let h = LatencyHist::new();
    for &s in &samples {
        h.record(s);
    }
    let mut sorted = samples.clone();
    sorted.sort_unstable();
    for i in 0..=100 {
        let q = i as f64 / 100.0;
        assert_eq!(h.quantile(q), reference_quantile(&sorted, q), "q={q}");
    }
    // count/sum/min/max are exact, not bucketized.
    assert_eq!(h.count(), samples.len() as u64);
    assert_eq!(h.sum_ns(), samples.iter().sum::<u64>());
    assert_eq!(h.min_ns(), sorted[0]);
    assert_eq!(h.max_ns(), *sorted.last().unwrap());
}

#[test]
fn quantiles_are_monotone_in_q() {
    let mut rng = Lcg(99);
    let h = LatencyHist::new();
    for _ in 0..2000 {
        h.record(rng.sample());
    }
    let mut prev = 0u64;
    for i in 0..=1000 {
        let v = h.quantile(i as f64 / 1000.0);
        assert!(v >= prev, "quantile not monotone at q={}", i as f64 / 1000.0);
        prev = v;
    }
}

#[test]
fn merge_is_indistinguishable_from_concatenation() {
    let mut rng = Lcg(1234);
    let a: Vec<u64> = (0..1500).map(|_| rng.sample()).collect();
    let b: Vec<u64> = (0..700).map(|_| rng.sample()).collect();

    let ha = LatencyHist::new();
    let hb = LatencyHist::new();
    let hc = LatencyHist::new();
    for &v in &a {
        ha.record(v);
        hc.record(v);
    }
    for &v in &b {
        hb.record(v);
        hc.record(v);
    }
    ha.merge(&hb);
    assert_eq!(ha.snapshot(), hc.snapshot());
    assert_eq!(ha.stats(), hc.stats());
}

#[test]
fn concurrent_recording_loses_no_samples() {
    use std::sync::Arc;
    let h = Arc::new(LatencyHist::new());
    let per_thread = 10_000u64;
    let threads: Vec<_> = (0..8u64)
        .map(|t| {
            let h = Arc::clone(&h);
            std::thread::spawn(move || {
                let mut rng = Lcg(t + 1);
                let mut sum = 0u64;
                for _ in 0..per_thread {
                    let v = rng.sample();
                    sum += v;
                    h.record(v);
                }
                sum
            })
        })
        .collect();
    let expected_sum: u64 = threads.into_iter().map(|t| t.join().unwrap()).sum();
    assert_eq!(h.count(), 8 * per_thread);
    assert_eq!(h.sum_ns(), expected_sum);
    // Bucket counts account for every sample too.
    let total: u64 = h.snapshot().nonzero.iter().map(|&(_, c)| c).sum();
    assert_eq!(total, 8 * per_thread);
}

/// The acceptance gate: exported p50/p95/p99 and stage breakdowns are
/// exact with respect to the recorded samples — computed independently
/// here from the raw durations and compared against both the snapshot
/// and the exposition the exporters render.
#[test]
fn exported_percentiles_and_stages_are_exact_for_recorded_samples() {
    let metrics = Metrics::default();
    let mut rng = Lcg(42);
    let mut queue_ns: Vec<u64> = Vec::new();
    let mut service_ns: Vec<u64> = Vec::new();
    for _ in 0..257 {
        metrics.job_submitted();
        let q = rng.sample();
        let s = rng.sample();
        queue_ns.push(q);
        service_ns.push(s);
        metrics.job_completed(Duration::from_nanos(q), Duration::from_nanos(s), 3);
    }
    queue_ns.sort_unstable();
    service_ns.sort_unstable();

    let snap = metrics.snapshot();
    for (dist, sorted) in [(&snap.queue_wait, &queue_ns), (&snap.service, &service_ns)] {
        assert_eq!(dist.count, 257);
        assert_eq!(dist.p50_ns, reference_quantile(sorted, 0.50));
        assert_eq!(dist.p95_ns, reference_quantile(sorted, 0.95));
        assert_eq!(dist.p99_ns, reference_quantile(sorted, 0.99));
        assert_eq!(dist.max_ns, *sorted.last().unwrap());
        assert_eq!(dist.mean_ns, sorted.iter().sum::<u64>() as f64 / 257.0);
    }

    // Stage breakdowns carry the exact totals of the same samples.
    let qs = snap.stage_stats(Stage::Queue).unwrap();
    assert_eq!(qs.count, 257);
    assert_eq!(qs.total_s, queue_ns.iter().sum::<u64>() as f64 / 1e9);
    assert_eq!(qs.max_s, *queue_ns.last().unwrap() as f64 / 1e9);
    let es = snap.stage_stats(Stage::Execute).unwrap();
    assert_eq!(es.total_s, service_ns.iter().sum::<u64>() as f64 / 1e9);

    // Both exporters publish those exact values, not re-derivations.
    let e = snap.exposition();
    for (name, sorted) in [("repro_queue_wait", &queue_ns), ("repro_service", &service_ns)] {
        for (stat, q) in [("p50", 0.50), ("p95", 0.95), ("p99", 0.99)] {
            assert_eq!(
                e.get(&format!("{name}_seconds"), &[("stat", stat)]),
                Some(reference_quantile(sorted, q) as f64 / 1e9),
                "{name} {stat}"
            );
        }
        assert_eq!(
            e.get(&format!("{name}_seconds"), &[("stat", "max")]),
            Some(*sorted.last().unwrap() as f64 / 1e9)
        );
    }
    let labels = [("stage", "queue")];
    assert_eq!(e.get("repro_stage_spans_total", &labels), Some(257.0));
    assert_eq!(
        e.get("repro_stage_seconds_total", &labels),
        Some(queue_ns.iter().sum::<u64>() as f64 / 1e9)
    );
    // And the JSON exporter renders from the same Exposition, so one
    // spot-check of structural agreement suffices.
    let json = Json::parse(&snap.to_json_line()).unwrap();
    assert_eq!(
        json.get("repro_jobs_completed_total").and_then(Json::as_f64),
        Some(257.0)
    );
}

fn small_image() -> FeatureVector {
    let s = generate_slice(&PhantomConfig {
        seed: 11,
        ..PhantomConfig::default()
    });
    FeatureVector::from_image(&s.image)
}

fn opts() -> EngineOpts {
    EngineOpts {
        threads: common::engine_threads(),
        ..EngineOpts::default()
    }
}

/// Tracing must be result-neutral: the same engine run with the
/// profiler armed and disarmed produces bit-identical output, and the
/// profile reflects the run (one sample per iteration).
#[test]
fn engine_profiling_is_result_neutral() {
    let params = FcmParams::default();
    let fv = small_image();
    for engine in [Engine::Sequential, Engine::Parallel, Engine::Histogram, Engine::Spatial] {
        let backend = backend_for(engine, None, &opts()).unwrap();
        let plain = backend.segment(&fv, &params).unwrap().run;

        prof::begin(params.max_iters * 2);
        let traced = backend.segment(&fv, &params).unwrap().run;
        let profile = prof::take().expect("profile armed");

        assert_eq!(plain.labels, traced.labels, "{engine:?} labels drifted under tracing");
        assert_eq!(plain.centers, traced.centers, "{engine:?} centers drifted");
        assert_eq!(plain.iterations, traced.iterations, "{engine:?} iterations drifted");
        assert!(
            !profile.iters.is_empty(),
            "{engine:?} recorded no iteration samples"
        );
        assert_eq!(profile.dropped_iters, 0, "{engine:?}");
        assert!(
            profile.iters.iter().all(|s| s.wall_ns > 0),
            "{engine:?} zero-width iteration sample"
        );
    }
}

/// Streamed runs profile tile I/O and stay result-neutral too.
#[test]
fn streamed_profiling_is_result_neutral_and_counts_tiles() {
    let params = FcmParams::default();
    let vol = {
        // An 8x8x6 synthetic ramp volume, deterministic.
        let voxels: Vec<u8> = (0..8 * 8 * 6).map(|i| (i * 7 % 251) as u8).collect();
        repro::image::VoxelVolume::from_voxels(8, 8, 6, voxels)
    };
    let backend = backend_for(Engine::Histogram, None, &opts()).unwrap();

    let mut src = vol.clone();
    let mut plain_sink: Vec<u8> = Vec::new();
    backend
        .segment_volume_streamed(&mut src, &mut plain_sink, &params, 2)
        .unwrap();

    prof::begin(params.max_iters);
    let mut src = vol.clone();
    let mut traced_sink: Vec<u8> = Vec::new();
    backend
        .segment_volume_streamed(&mut src, &mut traced_sink, &params, 2)
        .unwrap();
    let profile = prof::take().expect("profile armed");

    assert_eq!(plain_sink, traced_sink, "streamed output drifted under tracing");
    assert!(profile.tile_reads > 0, "no tile reads recorded");
    assert!(profile.tile_writes > 0, "no tile writes recorded");
    assert!(!profile.iters.is_empty(), "no iteration samples recorded");
}

/// End-to-end job trace: a service job's TraceLog carries the full
/// lifecycle (submit -> queue -> execute -> finish) plus the absorbed
/// engine profile, with exact per-stage totals.
#[test]
fn service_job_trace_records_the_lifecycle() {
    let mut cfg = repro::config::Config::new();
    cfg.service.workers = 1;
    let service = Service::start(&cfg).unwrap();
    let t = service
        .submit(small_image(), FcmParams::default(), Engine::Parallel)
        .unwrap();
    let trace = t.trace();
    let id = t.id;
    let r = t.wait().unwrap();
    let snap = service.shutdown();

    let summary = trace.summary();
    assert_eq!(summary.id, id);
    for stage in [Stage::Submit, Stage::Queue, Stage::Execute, Stage::Finish] {
        assert_eq!(summary.stage(stage).count, 1, "{stage:?}");
    }
    // The engine profile was absorbed: one iteration event per engine
    // iteration, and the iteration total is bounded by execute wall.
    let iters = summary.stage(Stage::Iteration);
    assert_eq!(iters.count, r.iterations as u64);
    assert!(iters.total_ns > 0);
    assert!(iters.total_ns <= summary.stage(Stage::Execute).total_ns);
    // Queue span is consistent with the result's own reading (same
    // measurement, one trip through f64 seconds).
    let queue = summary.stage(Stage::Queue);
    assert!((queue.total_ns as f64 / 1e9 - r.queue_wait_s).abs() < 1e-6);

    // The service metrics saw the same job: iteration histogram fed,
    // stage rollups present in the exposition.
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.iteration.count, r.iterations as u64);
    let e = snap.exposition();
    assert_eq!(
        e.get("repro_stage_spans_total", &[("stage", "iteration")]),
        Some(r.iterations as f64)
    );
    for line in snap.to_prometheus().lines() {
        assert_eq!(repro::obs::export::check_exposition_line(line), None, "{line:?}");
    }
}

/// The per-job run record built from a real service trace parses and
/// carries the stage the trace recorded.
#[test]
fn run_record_from_service_trace_roundtrips() {
    let mut cfg = repro::config::Config::new();
    cfg.service.workers = 1;
    let service = Service::start(&cfg).unwrap();
    let t = service
        .submit(small_image(), FcmParams::default(), Engine::Sequential)
        .unwrap();
    let trace = t.trace();
    let id = t.id;
    let r = t.wait().unwrap();
    service.shutdown();

    let summary = trace.summary();
    let rec = repro::obs::export::run_record_with_summary(
        &repro::obs::export::RunMeta {
            id,
            cmd: "serve",
            engine: "Sequential",
            shape: vec![181, 217],
            iterations: r.iterations as u64,
            converged: r.converged,
            wall_s: r.service_s,
            peak_resident_bytes: None,
            cache_hit: Some(r.cached),
        },
        &summary,
    );
    let text = rec.to_string();
    assert!(!text.contains('\n'));
    let back = Json::parse(&text).unwrap();
    assert_eq!(back.get("id").and_then(Json::as_f64), Some(id as f64));
    let exec = back.get("stages").and_then(|s| s.get("execute")).unwrap();
    assert_eq!(exec.get("count").and_then(Json::as_f64), Some(1.0));
    assert_eq!(
        back.get("stages")
            .and_then(|s| s.get("iteration"))
            .and_then(|i| i.get("count"))
            .and_then(Json::as_f64),
        Some(r.iterations as f64)
    );
}
