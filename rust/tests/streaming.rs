//! Integration suite for out-of-core streaming execution (PR 4 + the
//! PR 5 spatial/prefetch extensions):
//!
//! * the acceptance gates — a file-backed RVOL volume several times
//!   larger than the tile budget segments via the streamed path with
//!   output **byte-identical** to the in-memory `segment_volume`,
//!   across tile sizes {1, 3, 17} x thread counts {1, 2, 8} — for the
//!   histogram, slab, AND halo-streamed spatial paths (the spatial
//!   matrix also sweeps q ∈ {0, q>0}) — with the peak-resident metric
//!   bounded by the tile, not the volume;
//! * the CLI contract — a streamed label RVOL (rendered through
//!   `LabelScaler`) equals `save_raw(from_labels(...))` of the
//!   in-memory run, byte for byte;
//! * masked (skull-stripped) volumes through the paired-file reader;
//! * [`TilePrefetcher`] transparency (prefetch reorders I/O only) and
//!   [`PgmStackSource`] streaming through the same seam;
//! * the 16-bit RVOL raster (PR 7): u8-valued wide files bit-identical
//!   to the u8 files, 65 536-bin work/memory accounting, the wide
//!   tile/thread matrix, and masked u16 sentinels;
//! * streamed volume jobs end-to-end through the service, including
//!   concurrent-job high-water metrics and error propagation.

mod common;

use repro::config::Config;
use repro::coordinator::{backend_for, Engine, Service, StreamVolumeJob};
use repro::fcm::engine::stream::{estimated_peak_resident_bytes_wide, run_streamed, StreamOpts};
use repro::fcm::spatial::SpatialParams;
use repro::fcm::{Backend, EngineOpts, FcmParams};
use repro::image::volume::stream::{
    materialize, LabelScaler, PgmStackSource, RvolReader, RvolWriter, TilePrefetcher, VoxelSource,
};
use repro::image::{volume, VoxelVolume};
use repro::phantom::{generate_volume, PhantomConfig};
use std::path::PathBuf;

fn phantom_rvol(width: usize, height: usize, depth: usize) -> VoxelVolume {
    // Mid-brain slices when they fit the axis, lower start for deep
    // volumes (the slice axis runs 0..181).
    let start = 90usize.min(181 - depth);
    generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
    .to_voxel_volume()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("stream_it_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn streamed_rvol_bit_identical_across_tiles_and_threads() {
    // THE acceptance gate: file-backed streaming equals the in-memory
    // path exactly, for every tile size and thread count.
    let vol = phantom_rvol(41, 47, 19);
    let dir = tmp_dir("equiv");
    let path = dir.join("v.rvol");
    volume::save_raw(&vol, &path).unwrap();
    let params = FcmParams::default();

    for engine in [Engine::Parallel, Engine::Histogram, Engine::Spatial] {
        let mem = backend_for(engine, None, &EngineOpts::default())
            .unwrap()
            .segment_volume(&vol, &params)
            .unwrap();
        for threads in [1usize, 2, 8] {
            let opts = EngineOpts {
                threads,
                ..EngineOpts::default()
            };
            let backend = backend_for(engine, None, &opts).unwrap();
            for tile in [1usize, 3, 17] {
                let mut src = RvolReader::open(&path).unwrap();
                let mut sink = Vec::new();
                let out = backend
                    .segment_volume_streamed(&mut src, &mut sink, &params, tile)
                    .unwrap();
                assert!(out.streamed, "{engine:?} t={threads} tile={tile}");
                assert_eq!(
                    sink, mem.labels,
                    "{engine:?} t={threads} tile={tile}: labels diverged"
                );
                assert_eq!(out.centers, mem.centers, "{engine:?} t={threads} tile={tile}");
                assert_eq!(out.iterations, mem.iterations);
                assert_eq!(out.converged, mem.converged);
                assert_eq!(out.voxels, vol.len());
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn streamed_histogram_memory_is_bounded_by_the_tile() {
    // A volume several times larger than the tile budget must segment
    // with peak resident tile bytes (a) at least 4x below the volume
    // and (b) EQUAL for a 4x-deeper volume — the "bounded by the tile,
    // not the volume" pin, on the counter rather than the clock.
    let dir = tmp_dir("mem");
    let params = FcmParams::default();
    let backend = backend_for(Engine::Histogram, None, &EngineOpts::default()).unwrap();
    let mut peaks = Vec::new();
    for depth in [37usize, 148] {
        let vol = phantom_rvol(45, 53, depth);
        let path = dir.join(format!("v{depth}.rvol"));
        volume::save_raw(&vol, &path).unwrap();
        let mut src = RvolReader::open(&path).unwrap();
        let mut sink = Vec::new();
        let out = backend
            .segment_volume_streamed(&mut src, &mut sink, &params, 1)
            .unwrap();
        assert!(out.streamed);
        assert_eq!(sink.len(), vol.len());
        if depth == 148 {
            assert!(
                out.peak_resident_bytes * 4 <= vol.size_bytes(),
                "peak {} bytes vs volume {} bytes: not out-of-core",
                out.peak_resident_bytes,
                vol.size_bytes()
            );
        }
        peaks.push(out.peak_resident_bytes);
    }
    assert_eq!(
        peaks[0], peaks[1],
        "peak resident bytes must depend on the tile, not the depth"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn streamed_label_rvol_matches_in_memory_cli_output() {
    // The CLI contract behind the CI smoke job: --stream --out-raw
    // produces the same bytes as the in-memory --out-raw (labels
    // rendered to grey levels, RVOL-framed).
    let vol = phantom_rvol(33, 39, 11);
    let dir = tmp_dir("cli");
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let params = FcmParams::default();
    let backend = backend_for(Engine::Histogram, None, &EngineOpts::default()).unwrap();

    // In-memory path, as `segment_volume` + `--out-raw` writes it.
    let mem = backend.segment_volume(&vol, &params).unwrap();
    let mem_path = dir.join("mem.rvol");
    volume::save_raw(
        &VoxelVolume::from_labels(
            vol.width,
            vol.height,
            vol.depth,
            &mem.labels,
            params.clusters as u8,
        ),
        &mem_path,
    )
    .unwrap();

    // Streamed path, as `segment-volume --stream --out-raw` writes it.
    let stream_path = dir.join("stream.rvol");
    let mut src = RvolReader::open(&input).unwrap();
    let mut sink = LabelScaler::new(
        RvolWriter::create(&stream_path, vol.width, vol.height, vol.depth).unwrap(),
        params.clusters as u8,
    );
    backend
        .segment_volume_streamed(&mut src, &mut sink, &params, 4)
        .unwrap();
    sink.into_inner().finish().unwrap();

    assert_eq!(
        std::fs::read(&mem_path).unwrap(),
        std::fs::read(&stream_path).unwrap(),
        "streamed output file must be byte-identical to the in-memory one"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn masked_rvol_streams_through_the_paired_reader() {
    let base = phantom_rvol(31, 35, 7);
    let mut mask = vec![1u8; base.len()];
    for i in (0..base.len()).step_by(6) {
        mask[i] = 0;
    }
    let masked = base.clone().with_mask(mask.clone());
    let dir = tmp_dir("mask");
    let vp = dir.join("v.rvol");
    let mp = dir.join("m.rvol");
    volume::save_raw(&base, &vp).unwrap();
    volume::save_raw(
        &VoxelVolume::from_voxels(base.width, base.height, base.depth, mask.clone()),
        &mp,
    )
    .unwrap();
    let params = FcmParams::default();

    for engine in [Engine::Parallel, Engine::Histogram] {
        let backend = backend_for(engine, None, &EngineOpts::default()).unwrap();
        // The in-memory reference over the same masked volume.
        let mem = backend.segment_volume(&masked, &params).unwrap();
        let mut src = RvolReader::with_mask(&vp, &mp).unwrap();
        // Sanity: the paired reader reconstructs the masked volume.
        assert_eq!(materialize(&mut src).unwrap(), masked);
        let mut sink = Vec::new();
        let out = backend
            .segment_volume_streamed(&mut src, &mut sink, &params, 3)
            .unwrap();
        assert_eq!(sink, mem.labels, "{engine:?}");
        assert_eq!(out.centers, mem.centers, "{engine:?}");
        for (i, (&l, &mk)) in sink.iter().zip(&mask).enumerate() {
            if mk == 0 {
                assert_eq!(l, 0, "{engine:?}: masked voxel {i} lost the sentinel");
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Spread an 8-bit phantom across the full 16-bit range with a small
/// deterministic per-voxel jitter, so thousands of distinct levels are
/// genuinely occupied — a real wide-histogram workload, not 256 levels
/// renamed.
fn wide_voxels(vol: &VoxelVolume) -> Vec<u16> {
    vol.voxels
        .iter()
        .enumerate()
        .map(|(i, &v)| v as u16 * 256 + (i % 251) as u16)
        .collect()
}

#[test]
fn u16_rvol_with_u8_values_streams_bit_identical_to_the_u8_file() {
    // Decode/equivalence gate for the 16-bit raster: a wide file whose
    // samples all fit in 8 bits must land on exactly the u8 file's
    // bytes for both wide paths — the tile engines see the identical
    // f32 mirror, and the 65 536-bin histogram's extra bins carry zero
    // weight (exact no-ops in the fused pass, see DESIGN.md). Only the
    // histogram work counter may differ: the bin axis widens to 65 536.
    let vol = phantom_rvol(29, 31, 9);
    let dir = tmp_dir("u16_narrow");
    let p8 = dir.join("v8.rvol");
    let p16 = dir.join("v16.rvol");
    volume::save_raw(&vol, &p8).unwrap();
    let as_u16: Vec<u16> = vol.voxels.iter().map(|&v| v as u16).collect();
    volume::save_raw_u16(vol.width, vol.height, vol.depth, &as_u16, &p16).unwrap();
    let params = FcmParams::default();
    for backend in [Backend::Parallel, Backend::Histogram] {
        let opts = StreamOpts {
            backend,
            threads: 2,
            tile_slices: 3,
        };
        let mut sink8 = Vec::new();
        let out8 =
            run_streamed(&mut RvolReader::open(&p8).unwrap(), &mut sink8, &params, &opts).unwrap();
        let mut sink16 = Vec::new();
        let out16 = run_streamed(&mut RvolReader::open(&p16).unwrap(), &mut sink16, &params, &opts)
            .unwrap();
        assert_eq!(sink16, sink8, "{backend:?}: labels diverged across sample widths");
        assert_eq!(out16.centers, out8.centers, "{backend:?}");
        assert_eq!(out16.iterations, out8.iterations, "{backend:?}");
        assert_eq!(out16.jm_history, out8.jm_history, "{backend:?}");
        if matches!(backend, Backend::Histogram) {
            assert_eq!(out8.work_per_iter, 256);
            assert_eq!(out16.work_per_iter, 1 << 16);
        } else {
            assert_eq!(out8.work_per_iter, vol.len());
            assert_eq!(out16.work_per_iter, vol.len());
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wide_histogram_work_and_memory_are_level_and_tile_bounded() {
    // Genuinely 16-bit volumes (thousands of occupied levels) at two
    // depths: the histogram path's per-iteration work is the 65 536-bin
    // axis for both — independent of voxel count — and for both wide
    // paths the measured peak resident bytes equal the 2-byte-raster
    // estimator exactly and depend on the tile, not the depth.
    let dir = tmp_dir("u16_work");
    let params = FcmParams::default();
    for backend in [Backend::Histogram, Backend::Parallel] {
        let opts = StreamOpts {
            backend,
            threads: 0,
            tile_slices: 4,
        };
        let mut peaks = Vec::new();
        for depth in [6usize, 18] {
            let vol = phantom_rvol(27, 29, depth);
            let path = dir.join(format!("v{depth}_{backend:?}.rvol"));
            volume::save_raw_u16(vol.width, vol.height, vol.depth, &wide_voxels(&vol), &path)
                .unwrap();
            let mut src = RvolReader::open(&path).unwrap();
            assert_eq!(src.sample_bits(), 16);
            let mut sink = Vec::new();
            let out = run_streamed(&mut src, &mut sink, &params, &opts).unwrap();
            assert_eq!(sink.len(), vol.len());
            assert_eq!(out.voxels, vol.len());
            if matches!(backend, Backend::Histogram) {
                assert_eq!(out.work_per_iter, 1 << 16, "work must track levels, not voxels");
            } else {
                assert_eq!(out.work_per_iter, vol.len());
            }
            assert_eq!(
                out.peak_resident_bytes,
                estimated_peak_resident_bytes_wide(
                    vol.width * vol.height,
                    depth,
                    params.clusters,
                    2,
                    &opts
                ),
                "{backend:?} depth {depth}: estimator drifted from the measured peak"
            );
            peaks.push(out.peak_resident_bytes);
        }
        assert_eq!(peaks[0], peaks[1], "{backend:?}: peak must depend on the tile, not depth");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn wide_u16_stream_bit_identical_across_tiles_and_threads() {
    // The thread/tile matrix for the wide raster: each engine must be
    // bit-identical to itself across tile sizes {1, 3, 17} x threads
    // {1, 2, 8} — the fixed lane-major reduction order, exactly as for
    // u8. There is no in-memory u16 reference (the raster is
    // streaming-only), so the pin is this self-consistency matrix plus
    // the golden u16 fixtures.
    let vol = phantom_rvol(25, 27, 10);
    let dir = tmp_dir("u16_matrix");
    let path = dir.join("v.rvol");
    volume::save_raw_u16(vol.width, vol.height, vol.depth, &wide_voxels(&vol), &path).unwrap();
    let params = FcmParams::default();
    for backend in [Backend::Parallel, Backend::Histogram] {
        let mut reference: Option<(Vec<u8>, Vec<f32>, usize)> = None;
        for threads in [1usize, 2, 8] {
            for tile in [1usize, 3, 17] {
                let opts = StreamOpts {
                    backend,
                    threads,
                    tile_slices: tile,
                };
                let mut src = RvolReader::open(&path).unwrap();
                let mut sink = Vec::new();
                let out = run_streamed(&mut src, &mut sink, &params, &opts).unwrap();
                match &reference {
                    None => reference = Some((sink, out.centers, out.iterations)),
                    Some((labels, centers, iterations)) => {
                        assert_eq!(&sink, labels, "{backend:?} t={threads} tile={tile}");
                        assert_eq!(&out.centers, centers, "{backend:?} t={threads} tile={tile}");
                        assert_eq!(out.iterations, *iterations, "{backend:?} t={threads}");
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn masked_u16_stream_pins_the_sentinel() {
    // A 16-bit volume paired with an 8-bit mask RVOL: excluded voxels
    // come out as the 0 sentinel for both wide paths.
    let vol = phantom_rvol(23, 25, 6);
    let mut mask = vec![1u8; vol.len()];
    for i in (0..mask.len()).step_by(5) {
        mask[i] = 0;
    }
    let dir = tmp_dir("u16_mask");
    let vp = dir.join("v.rvol");
    let mp = dir.join("m.rvol");
    volume::save_raw_u16(vol.width, vol.height, vol.depth, &wide_voxels(&vol), &vp).unwrap();
    volume::save_raw(
        &VoxelVolume::from_voxels(vol.width, vol.height, vol.depth, mask.clone()),
        &mp,
    )
    .unwrap();
    let params = FcmParams::default();
    for backend in [Backend::Parallel, Backend::Histogram] {
        let mut src = RvolReader::with_mask(&vp, &mp).unwrap();
        assert_eq!(src.bytes_per_voxel(), 2);
        assert!(src.has_mask());
        let mut sink = Vec::new();
        let opts = StreamOpts {
            backend,
            ..StreamOpts::default()
        };
        run_streamed(&mut src, &mut sink, &params, &opts).unwrap();
        assert_eq!(sink.len(), vol.len());
        for (i, (&l, &mk)) in sink.iter().zip(&mask).enumerate() {
            if mk == 0 {
                assert_eq!(l, 0, "{backend:?}: masked voxel {i} lost the sentinel");
            }
        }
        assert!(sink.iter().any(|&l| l > 0), "{backend:?}: all labels zero");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn streamed_spatial_q_matrix_bit_identical() {
    // The PR-5 acceptance gate: the halo-streamed spatial path equals
    // the in-memory spatial engine byte-for-byte for tile sizes
    // {1, 3, 17} x threads {1, 2, 8} x q in {0, q > 0}, through the
    // serving seam and a real file-backed source.
    use repro::coordinator::backend::SpatialBackend;
    use repro::coordinator::FcmBackend;
    let vol = phantom_rvol(29, 33, 8);
    let dir = tmp_dir("spatial_q");
    let path = dir.join("v.rvol");
    volume::save_raw(&vol, &path).unwrap();
    let params = FcmParams::default();
    for q in [0.0f32, 1.0] {
        let sp = SpatialParams {
            q,
            ..SpatialParams::default()
        };
        let mem = SpatialBackend::with_params(&EngineOpts::default(), sp)
            .segment_volume(&vol, &params)
            .unwrap();
        for threads in [1usize, 2, 8] {
            let opts = EngineOpts {
                threads,
                ..EngineOpts::default()
            };
            let backend = SpatialBackend::with_params(&opts, sp);
            for tile in [1usize, 3, 17] {
                let mut src = RvolReader::open(&path).unwrap();
                let mut sink = Vec::new();
                let out = backend
                    .segment_volume_streamed(&mut src, &mut sink, &params, tile)
                    .unwrap();
                assert!(out.streamed, "q={q} t={threads} tile={tile}");
                assert_eq!(sink, mem.labels, "q={q} t={threads} tile={tile}");
                assert_eq!(out.centers, mem.centers, "q={q} t={threads} tile={tile}");
                assert_eq!(out.iterations, mem.iterations, "q={q} t={threads} tile={tile}");
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn prefetched_stream_is_byte_identical_to_direct() {
    // The prefetcher only reorders I/O: wrapping the source must change
    // nothing — labels, centers, iterations — for any engine, including
    // the halo-walking spatial path whose request stride differs.
    let vol = phantom_rvol(31, 37, 11);
    let dir = tmp_dir("prefetch");
    let path = dir.join("v.rvol");
    volume::save_raw(&vol, &path).unwrap();
    let params = FcmParams::default();
    let threads = common::engine_threads();
    let opts = EngineOpts {
        threads,
        ..EngineOpts::default()
    };
    for engine in [Engine::Histogram, Engine::Parallel, Engine::Spatial] {
        let backend = backend_for(engine, None, &opts).unwrap();
        for tile in [2usize, 5] {
            let mut direct_sink = Vec::new();
            let direct = {
                let mut src = RvolReader::open(&path).unwrap();
                backend
                    .segment_volume_streamed(&mut src, &mut direct_sink, &params, tile)
                    .unwrap()
            };
            let mut pf_sink = Vec::new();
            let prefetched = {
                let mut src = TilePrefetcher::wrap(RvolReader::open(&path).unwrap());
                backend
                    .segment_volume_streamed(&mut src, &mut pf_sink, &params, tile)
                    .unwrap()
            };
            assert_eq!(pf_sink, direct_sink, "{engine:?} tile {tile}");
            assert_eq!(prefetched.centers, direct.centers, "{engine:?} tile {tile}");
            assert_eq!(prefetched.iterations, direct.iterations, "{engine:?}");
            assert_eq!(
                prefetched.peak_resident_bytes, direct.peak_resident_bytes,
                "{engine:?}: the engine-side resident metric must not see the prefetcher"
            );
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pgm_stack_streams_through_the_same_seam() {
    // A per-slice PGM directory is a first-class streaming source: the
    // streamed run over PgmStackSource equals both the in-memory load
    // and the RVOL streaming of the same field, byte for byte.
    let vol = phantom_rvol(27, 31, 9);
    let dir = tmp_dir("pgmstack");
    let stack = dir.join("slices");
    volume::save_pgm_stack(&vol, &stack).unwrap();
    let rvol = dir.join("v.rvol");
    volume::save_raw(&vol, &rvol).unwrap();
    assert_eq!(volume::load_pgm_stack(&stack).unwrap(), vol);
    let params = FcmParams::default();
    for engine in [Engine::Histogram, Engine::Parallel] {
        let backend = backend_for(engine, None, &EngineOpts::default()).unwrap();
        let mem = backend.segment_volume(&vol, &params).unwrap();
        let mut stack_sink = Vec::new();
        let mut src = PgmStackSource::open(&stack).unwrap();
        assert_eq!(
            (src.width(), src.height(), src.depth()),
            (vol.width, vol.height, vol.depth)
        );
        let out = backend
            .segment_volume_streamed(&mut src, &mut stack_sink, &params, 4)
            .unwrap();
        assert!(out.streamed, "{engine:?}");
        assert_eq!(stack_sink, mem.labels, "{engine:?}: PGM stack diverged");
        let mut rvol_sink = Vec::new();
        let mut rsrc = RvolReader::open(&rvol).unwrap();
        backend
            .segment_volume_streamed(&mut rsrc, &mut rvol_sink, &params, 4)
            .unwrap();
        assert_eq!(stack_sink, rvol_sink, "{engine:?}: sources disagree");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn service_streams_pgm_stack_jobs_with_prefetch() {
    // StreamVolumeJob.input may name a PGM-stack directory; the worker
    // routes it through PgmStackSource (+ prefetch) and the output RVOL
    // holds the in-memory path's canonical labels.
    let vol = phantom_rvol(25, 29, 7);
    let dir = tmp_dir("svc_stack");
    let stack = dir.join("slices");
    volume::save_pgm_stack(&vol, &stack).unwrap();
    let cfg = Config::new();
    let params = FcmParams::from(&cfg.fcm);
    let service = Service::start(&cfg).unwrap();
    let output = dir.join("seg.rvol");
    let r = service
        .submit_volume_streamed(
            StreamVolumeJob {
                input: stack.clone(),
                mask: None,
                output: output.clone(),
                tile_slices: 3,
                prefetch: true,
                fault: None,
            },
            params,
            Engine::Parallel,
        )
        .unwrap()
        .wait()
        .unwrap();
    let direct = backend_for(Engine::Parallel, None, &EngineOpts::from(&cfg.engine))
        .unwrap()
        .segment_volume(&vol, &params)
        .unwrap();
    assert_eq!(volume::load_raw(&output).unwrap().voxels, direct.labels);
    assert_eq!(r.centers, direct.centers);
    // A mask paired with a directory input is a per-job error.
    let r = service
        .submit_volume_streamed(
            StreamVolumeJob {
                input: stack.clone(),
                mask: Some(dir.join("nope.rvol")),
                output: dir.join("never.rvol"),
                tile_slices: 3,
                prefetch: false,
                fault: None,
            },
            params,
            Engine::Parallel,
        )
        .unwrap()
        .wait();
    assert!(r.is_err());
    let snap = service.shutdown();
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.failed, 1);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn service_stream_metrics_track_high_water_across_concurrent_jobs() {
    // The PR-4 gap this PR closes: stream_peak_resident_bytes is a
    // fetch_max high-water mark — under CONCURRENT streamed jobs with
    // different tile budgets it must land on exactly the largest
    // per-job peak, and streamed_runs must count every success.
    let dir = tmp_dir("svc_conc");
    let vol = phantom_rvol(33, 37, 12);
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 2;
    let params = FcmParams::from(&cfg.fcm);
    let service = Service::start(&cfg).unwrap();
    // Mixed tile budgets (and prefetch settings) in flight at once.
    let specs: Vec<StreamVolumeJob> = [1usize, 2, 4, 6]
        .iter()
        .enumerate()
        .map(|(i, &tile)| StreamVolumeJob {
            input: input.clone(),
            mask: None,
            output: dir.join(format!("seg{i}.rvol")),
            tile_slices: tile,
            prefetch: i % 2 == 0,
            fault: None,
        })
        .collect();
    let tickets: Vec<_> = specs
        .iter()
        .map(|spec| {
            service
                .submit_volume_streamed(spec.clone(), params, Engine::Histogram)
                .unwrap()
        })
        .collect();
    let mut peaks = Vec::new();
    for t in tickets {
        let r = t.wait().unwrap();
        peaks.push(r.peak_resident_bytes.expect("streamed jobs report peak bytes") as u64);
    }
    // A failing job (missing input) must not bump the streamed counters.
    assert!(service
        .submit_volume_streamed(
            StreamVolumeJob {
                input: dir.join("missing.rvol"),
                mask: None,
                output: dir.join("never.rvol"),
                tile_slices: 2,
                prefetch: true,
                fault: None,
            },
            params,
            Engine::Histogram,
        )
        .unwrap()
        .wait()
        .is_err());
    let snap = service.shutdown();
    assert_eq!(snap.streamed_runs, 4);
    assert_eq!(snap.completed, 4);
    assert_eq!(snap.failed, 1);
    assert_eq!(
        snap.stream_peak_resident_bytes,
        *peaks.iter().max().unwrap(),
        "high-water mark must be exactly the largest per-job peak"
    );
    assert!(peaks.iter().any(|&p| p != snap.stream_peak_resident_bytes));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn service_streamed_volume_jobs_end_to_end() {
    let vol = phantom_rvol(35, 41, 9);
    let dir = tmp_dir("svc");
    let input = dir.join("v.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let cfg = Config::new();
    let params = FcmParams::from(&cfg.fcm);
    let opts = EngineOpts::from(&cfg.engine);
    let service = Service::start(&cfg).unwrap();

    let mut outputs = Vec::new();
    for (i, engine) in [Engine::Histogram, Engine::Parallel].into_iter().enumerate() {
        let output = dir.join(format!("seg{i}.rvol"));
        let r = service
            .submit_volume_streamed(
                StreamVolumeJob {
                    input: input.clone(),
                    mask: None,
                    output: output.clone(),
                    tile_slices: 4,
                    prefetch: i % 2 == 0,
                    fault: None,
                },
                params,
                engine,
            )
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.engine, engine);
        assert!(r.labels.is_empty(), "streamed labels live in the file");
        let peak = r.peak_resident_bytes.expect("streamed jobs report peak bytes");
        assert!(peak > 0);
        // The output RVOL holds exactly the in-memory path's canonical
        // labels.
        let direct = backend_for(engine, None, &opts)
            .unwrap()
            .segment_volume(&vol, &params)
            .unwrap();
        let written = volume::load_raw(&output).unwrap();
        assert_eq!(written.voxels, direct.labels, "{engine:?}");
        assert_eq!(r.centers, direct.centers, "{engine:?}");
        assert_eq!(r.iterations, direct.iterations, "{engine:?}");
        outputs.push(output);
    }

    // A bad input path fails the job, never the worker.
    let r = service.submit_volume_streamed(
        StreamVolumeJob {
            input: dir.join("missing.rvol"),
            mask: None,
            output: dir.join("never.rvol"),
            tile_slices: 4,
            prefetch: true,
            fault: None,
        },
        params,
        Engine::Histogram,
    );
    assert!(r.unwrap().wait().is_err());

    let snap = service.shutdown();
    assert_eq!(snap.completed, 2);
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.streamed_runs, 2);
    assert!(snap.stream_peak_resident_bytes > 0);
    std::fs::remove_dir_all(&dir).unwrap();
}
