//! Shared integration-test helpers (a directory module, so cargo does
//! not compile it as its own test crate).

/// Engine lane count for the CI thread-matrix legs: `ENGINE_THREADS=N`
/// re-runs the deterministic suites at a pinned pool width (absent or
/// unparsable = 0 = all cores). Results must be identical for every
/// value — that is the invariant the matrix re-checks.
#[allow(dead_code)]
pub fn engine_threads() -> usize {
    std::env::var("ENGINE_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Whether device-path tests can run: artifacts present AND a real xla
/// crate linked (the vendored offline stub parses manifests but cannot
/// compile HLO). Prints the skip reason so `cargo test -q` output shows
/// why a device test was a no-op.
pub fn device_ready() -> bool {
    let ok = repro::runtime::device_available(std::path::Path::new("artifacts"));
    if !ok {
        eprintln!(
            "skipping device test: device path unavailable \
             (run `make artifacts` and link the real xla crate)"
        );
    }
    ok
}
