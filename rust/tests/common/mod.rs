//! Shared integration-test helpers (a directory module, so cargo does
//! not compile it as its own test crate).

/// Whether device-path tests can run: artifacts present AND a real xla
/// crate linked (the vendored offline stub parses manifests but cannot
/// compile HLO). Prints the skip reason so `cargo test -q` output shows
/// why a device test was a no-op.
pub fn device_ready() -> bool {
    let ok = repro::runtime::device_available(std::path::Path::new("artifacts"));
    if !ok {
        eprintln!(
            "skipping device test: device path unavailable \
             (run `make artifacts` and link the real xla crate)"
        );
    }
    ok
}
