//! Integration suite for the volumetric subsystem (PR 3):
//!
//! * the acceptance gates — a >= 40-slice phantom volume segments
//!   bit-identically across 1/2/8 threads, and the 3-D histogram path's
//!   per-iteration work is 256 bins regardless of voxel count;
//! * 3-D spatial regularization's noise robustness at the E11 collapse
//!   point (phantom noise sigma = 12);
//! * volume jobs end-to-end through the service (true-3D on the host
//!   backends, labels aligned with the submitted voxel field).

mod common;

use repro::config::Config;
use repro::coordinator::{backend_for, Engine, Service};
use repro::eval::dice_per_class;
use repro::fcm::engine::volume::{run_volume, VolumeOpts, BINS};
use repro::fcm::{canonical_relabel, spatial, Backend, EngineOpts, FcmParams};
use repro::phantom::{generate_volume, PhantomConfig, PhantomVolume};

fn phantom_volume(width: usize, height: usize, start: usize, depth: usize, noise: f32) -> PhantomVolume {
    generate_volume(
        &PhantomConfig {
            width,
            height,
            noise_sigma: noise,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
}

#[test]
fn forty_slice_volume_bit_identical_across_threads() {
    // Acceptance gate: >= 40 slices, 3-D segmentation identical to the
    // last bit for 1, 2, and 8 threads (and across slab sizes).
    let vol = phantom_volume(61, 73, 75, 41, 4.0).to_voxel_volume();
    assert!(vol.depth >= 40);
    let params = FcmParams {
        epsilon: 0.0, // run exactly max_iters everywhere
        max_iters: 10,
        ..FcmParams::default()
    };
    let reference = run_volume(
        &vol,
        &params,
        &VolumeOpts {
            backend: Backend::Parallel,
            threads: 1,
            slab_slices: 4,
        },
    );
    assert_eq!(reference.run.iterations, 10);
    // The CI thread-matrix leg re-runs this suite with ENGINE_THREADS
    // pinned; fold that lane count into the explicit sweep too.
    for (threads, slab) in [(2, 4), (8, 4), (8, 1), (8, 16), (common::engine_threads(), 4)] {
        let r = run_volume(
            &vol,
            &params,
            &VolumeOpts {
                backend: Backend::Parallel,
                threads,
                slab_slices: slab,
            },
        );
        assert_eq!(r.run.centers, reference.run.centers, "t={threads} slab={slab}");
        assert_eq!(r.run.u, reference.run.u, "t={threads} slab={slab}");
        assert_eq!(r.run.labels, reference.run.labels, "t={threads} slab={slab}");
        assert_eq!(r.run.jm_history, reference.run.jm_history, "t={threads} slab={slab}");
    }
}

#[test]
fn histogram_iteration_work_independent_of_voxel_count() {
    // Acceptance gate: the 3-D histogram path's per-iteration work is
    // the 256-bin table for a 2-slice and a 41-slice volume alike.
    let params = FcmParams::default();
    let small = phantom_volume(61, 73, 90, 2, 4.0).to_voxel_volume();
    let large = phantom_volume(61, 73, 75, 41, 4.0).to_voxel_volume();
    assert!(large.len() > 20 * small.len());
    let o = VolumeOpts::with_backend(Backend::Histogram);
    let a = run_volume(&small, &params, &o);
    let b = run_volume(&large, &params, &o);
    assert_eq!(a.work_per_iter, BINS);
    assert_eq!(b.work_per_iter, BINS);
    // The expansion is still per-voxel: labels cover the field.
    assert_eq!(b.run.labels.len(), large.len());
    assert!(b.run.iterations > 0);
}

#[test]
fn spatial_3d_rescues_sigma12_noise() {
    // E11's collapse case (fcm/spatial.rs): plain intensity FCM falls
    // apart at sigma = 12. The 3-D spatial engine must do at least as
    // well on mean CSF/GM/WM DSC — in practice clearly better, since the
    // 26-neighbour window averages noise over adjacent slices too.
    let pv = phantom_volume(121, 145, 93, 6, 12.0);
    let vol = pv.to_voxel_volume();
    let truth = pv.ground_truth_labels();
    let params = FcmParams::default();
    let vopts = VolumeOpts::default();

    let mut plain = run_volume(&vol, &params, &vopts);
    canonical_relabel(&mut plain.run);
    let mut spat = spatial::run_volume(&vol, &params, &spatial::SpatialParams::default(), &vopts);
    canonical_relabel(&mut spat.run);

    let mean_tissue = |labels: &[u8]| {
        let d = dice_per_class(labels, &truth, 4);
        (d[1] + d[2] + d[3]) / 3.0
    };
    let d_plain = mean_tissue(&plain.run.labels);
    let d_spat = mean_tissue(&spat.run.labels);
    assert!(
        d_spat + 1e-9 >= d_plain,
        "3-D spatial mean tissue DSC {d_spat:.4} must not trail plain {d_plain:.4}"
    );
    // And it must actually rescue a meaningful share, as 2-D spatial
    // does on single slices (fcm::spatial::tests).
    assert!(
        d_spat > d_plain + 0.02,
        "3-D spatial {d_spat:.4} vs plain {d_plain:.4}: no rescue"
    );
}

#[test]
fn spatial_volume_q_zero_is_plain_volumetric_fcm_bitwise() {
    let vol = phantom_volume(45, 55, 92, 4, 4.0).to_voxel_volume();
    let params = FcmParams::default();
    let vopts = VolumeOpts::default();
    let plain = run_volume(&vol, &params, &vopts);
    let spat = spatial::run_volume(
        &vol,
        &params,
        &spatial::SpatialParams {
            q: 0.0,
            ..Default::default()
        },
        &vopts,
    );
    assert_eq!(spat.run.centers, plain.run.centers);
    assert_eq!(spat.run.u, plain.run.u);
    assert_eq!(spat.run.labels, plain.run.labels);
    assert_eq!(spat.run.iterations, plain.run.iterations);
}

#[test]
fn service_volume_jobs_match_direct_backend_calls() {
    let pv = phantom_volume(45, 55, 92, 3, 4.0);
    let vol = pv.to_voxel_volume();
    let truth = pv.ground_truth_labels();
    let cfg = Config::new();
    let params = FcmParams::from(&cfg.fcm);
    let service = Service::start(&cfg).unwrap();
    let opts = EngineOpts::from(&cfg.engine);

    for engine in [Engine::Parallel, Engine::Histogram, Engine::Spatial] {
        let r = service
            .submit_volume(vol.clone(), params, engine)
            .unwrap()
            .wait()
            .unwrap();
        assert_eq!(r.engine, engine);
        assert_eq!(r.labels.len(), vol.len(), "{engine:?}");
        let direct = backend_for(engine, None, &opts)
            .unwrap()
            .segment_volume(&vol, &params)
            .unwrap();
        assert!(direct.true_3d, "{engine:?} must serve the true-3D path");
        assert_eq!(r.labels, direct.labels, "{engine:?}");
        assert_eq!(r.centers, direct.centers, "{engine:?}");
        assert_eq!(r.iterations, direct.iterations, "{engine:?}");
        // Sanity: the segmentation is anatomically plausible.
        let d = dice_per_class(&r.labels, &truth, 4);
        assert!(d[0] > 0.9, "{engine:?}: background DSC {:.3}", d[0]);
    }

    // The slice-loop fallback also serves volumes (sequential engine).
    let r = service
        .submit_volume(vol.clone(), params, Engine::Sequential)
        .unwrap()
        .wait()
        .unwrap();
    assert_eq!(r.labels.len(), vol.len());

    let snap = service.shutdown();
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.completed, 4);
    // Each volume job executed as its own singleton batch.
    assert!(snap.engine_stats(Engine::Parallel).unwrap().mean_batch_size <= 1.0 + 1e-9);
}

#[test]
fn volume_roundtrips_through_rvol_and_pgm_stack() {
    // The I/O formats preserve the exact field the engines consume.
    let vol = phantom_volume(33, 41, 95, 3, 4.0).to_voxel_volume();
    let dir = std::env::temp_dir().join(format!("vol3d_io_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let raw = dir.join("v.rvol");
    repro::image::volume::save_raw(&vol, &raw).unwrap();
    let vol2 = repro::image::volume::load_raw(&raw).unwrap();
    assert_eq!(vol, vol2);
    let stack = dir.join("slices");
    repro::image::volume::save_pgm_stack(&vol, &stack).unwrap();
    let vol3 = repro::image::volume::load_pgm_stack(&stack).unwrap();
    assert_eq!(vol, vol3);
    // Identical inputs -> identical segmentations.
    let params = FcmParams::default();
    let a = run_volume(&vol, &params, &VolumeOpts::default());
    let b = run_volume(&vol3, &params, &VolumeOpts::default());
    assert_eq!(a.run.labels, b.run.labels);
    std::fs::remove_dir_all(&dir).unwrap();
}
