//! Property-based tests (in-tree harness; the offline build has no
//! proptest). Each property runs against many seeded random cases via
//! Rng64; failures print the seed for deterministic reproduction.

use repro::eval::{dice_per_class, Confusion};
use repro::fcm::engine::stream::{run_streamed, run_streamed_spatial, StreamOpts};
use repro::fcm::spatial::SpatialParams;
use repro::fcm::{self, Backend, FcmParams};
use repro::image::volume::stream::{halo_range, tile_ranges};
use repro::image::volume::{self, VoxelVolume};
use repro::image::{pgm, GrayImage};
use repro::util::Rng64;

/// Run `f` for `cases` seeds, reporting the failing seed.
fn for_all_seeds(cases: u64, f: impl Fn(u64)) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if result.is_err() {
            panic!("property failed for seed {seed}");
        }
    }
}

fn random_intensities(rng: &mut Rng64, n: usize) -> Vec<f32> {
    // Mixture of 2-5 modes with random spreads — realistic FCM inputs.
    let k = 2 + (rng.below(4) as usize);
    let mus: Vec<f32> = (0..k).map(|_| rng.uniform(5.0, 250.0)).collect();
    (0..n)
        .map(|_| {
            let j = rng.below(k as u64) as usize;
            let sigma = rng.uniform(1.0, 12.0);
            rng.gauss(mus[j], sigma).clamp(0.0, 255.0)
        })
        .collect()
}

#[test]
fn prop_sequential_membership_rows_always_sum_to_one() {
    for_all_seeds(20, |seed| {
        let mut rng = Rng64::new(seed);
        let n = 200 + rng.below(2000) as usize;
        let c = 2 + rng.below(5) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: c,
                max_iters: 20,
                seed,
                ..Default::default()
            },
        );
        for i in 0..n {
            let s: f32 = (0..c).map(|j| run.u[j * n + i]).sum();
            assert!((s - 1.0).abs() < 1e-3, "pixel {i} sums to {s}");
            for j in 0..c {
                let u = run.u[j * n + i];
                assert!((0.0..=1.0 + 1e-5).contains(&u), "u[{j},{i}]={u}");
            }
        }
    });
}

#[test]
fn prop_sequential_objective_never_increases() {
    for_all_seeds(15, |seed| {
        let mut rng = Rng64::new(seed ^ 0xABCD);
        let n = 500 + rng.below(1500) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: 3,
                max_iters: 30,
                seed,
                ..Default::default()
            },
        );
        for pair in run.jm_history.windows(2) {
            assert!(pair[1] <= pair[0] * (1.0 + 1e-9), "{:?}", run.jm_history);
        }
    });
}

#[test]
fn prop_labels_in_range_and_centers_in_data_hull() {
    for_all_seeds(15, |seed| {
        let mut rng = Rng64::new(seed ^ 0x1234);
        let n = 300 + rng.below(1000) as usize;
        let c = 2 + rng.below(4) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: c,
                max_iters: 40,
                seed,
                ..Default::default()
            },
        );
        let (lo, hi) = x
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
                (l.min(v), h.max(v))
            });
        assert!(run.labels.iter().all(|&l| (l as usize) < c));
        for &v in &run.centers {
            assert!(v >= lo - 1.0 && v <= hi + 1.0, "center {v} outside [{lo},{hi}]");
        }
    });
}

#[test]
fn prop_defuzzify_picks_argmax() {
    for_all_seeds(25, |seed| {
        let mut rng = Rng64::new(seed ^ 0x77);
        let n = 1 + rng.below(200) as usize;
        let c = 2 + rng.below(5) as usize;
        let u: Vec<f32> = (0..c * n).map(|_| rng.next_f32()).collect();
        let labels = fcm::defuzzify(&u, c, n);
        for i in 0..n {
            let li = labels[i] as usize;
            for j in 0..c {
                assert!(u[li * n + i] >= u[j * n + i] || li == j);
            }
        }
    });
}

#[test]
fn prop_brfcm_lut_consistency_and_agreement() {
    for_all_seeds(8, |seed| {
        let mut rng = Rng64::new(seed ^ 0xBEEF);
        let n = 4000 + rng.below(8000) as usize;
        let px: Vec<u8> = random_intensities(&mut rng, n)
            .into_iter()
            .map(|v| v as u8)
            .collect();
        let br = fcm::brfcm::run_on_pixels(&px, &FcmParams { seed, ..Default::default() });
        for (i, &p) in px.iter().enumerate() {
            assert_eq!(br.labels[i], br.label_lut[p as usize]);
        }
    });
}

#[test]
fn prop_dice_bounds_and_symmetry() {
    for_all_seeds(30, |seed| {
        let mut rng = Rng64::new(seed ^ 0xD1CE);
        let n = 1 + rng.below(500) as usize;
        let a: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
        let b: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
        let dab = dice_per_class(&a, &b, 4);
        let dba = dice_per_class(&b, &a, 4);
        for (x, y) in dab.iter().zip(&dba) {
            assert!((x - y).abs() < 1e-12, "DSC not symmetric");
            assert!((0.0..=1.0).contains(x));
        }
        // Self-similarity is exactly 1.
        assert!(dice_per_class(&a, &a, 4).iter().all(|&d| d == 1.0));
    });
}

#[test]
fn prop_confusion_row_sums_match_truth_counts() {
    for_all_seeds(20, |seed| {
        let mut rng = Rng64::new(seed ^ 0xC0DE);
        let n = 1 + rng.below(400) as usize;
        let truth: Vec<u8> = (0..n).map(|_| rng.below(3) as u8).collect();
        let pred: Vec<u8> = (0..n).map(|_| rng.below(3) as u8).collect();
        let c = Confusion::new(&pred, &truth, 3);
        for t in 0..3usize {
            let row: u64 = (0..3).map(|p| c.at(t, p)).sum();
            let count = truth.iter().filter(|&&l| l == t as u8).count() as u64;
            assert_eq!(row, count);
        }
        assert_eq!(c.total() as usize, n);
    });
}

#[test]
fn prop_pgm_roundtrip_random_images() {
    for_all_seeds(20, |seed| {
        let mut rng = Rng64::new(seed ^ 0x9931);
        let w = 1 + rng.below(64) as usize;
        let h = 1 + rng.below(64) as usize;
        let px: Vec<u8> = (0..w * h).map(|_| rng.below(256) as u8).collect();
        let img = GrayImage::from_pixels(w, h, px);
        let mut buf = Vec::new();
        pgm::write_to(&img, &mut buf).unwrap();
        assert_eq!(pgm::parse(&buf).unwrap(), img);
    });
}

#[test]
fn prop_canonical_relabel_preserves_partition() {
    for_all_seeds(15, |seed| {
        let mut rng = Rng64::new(seed ^ 0x5150);
        let n = 100 + rng.below(400) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let mut run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: 3,
                max_iters: 25,
                seed,
                ..Default::default()
            },
        );
        let before: std::collections::HashMap<u8, usize> =
            run.labels.iter().fold(Default::default(), |mut m, &l| {
                *m.entry(l).or_default() += 1;
                m
            });
        fcm::canonical_relabel(&mut run);
        // Partition sizes are preserved as a multiset.
        let mut a: Vec<usize> = before.values().copied().collect();
        let after: std::collections::HashMap<u8, usize> =
            run.labels.iter().fold(Default::default(), |mut m, &l| {
                *m.entry(l).or_default() += 1;
                m
            });
        let mut b: Vec<usize> = after.values().copied().collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
        // Centers ascending.
        assert!(run.centers.windows(2).all(|p| p[0] <= p[1]));
    });
}

/// The streaming seam's tile geometry: every tile grid covers the depth
/// exactly once, in order, with no tile exceeding the budget.
#[test]
fn prop_tile_ranges_cover_exactly_once() {
    for_all_seeds(40, |seed| {
        let mut rng = Rng64::new(seed ^ 0x711E);
        let depth = rng.below(200) as usize;
        let tile = [1usize, 2, 3, 5, 17][rng.below(5) as usize];
        let ranges = tile_ranges(depth, tile);
        let mut expect_start = 0;
        for &(z0, nz) in &ranges {
            assert_eq!(z0, expect_start, "gap or overlap at {z0}");
            assert!((1..=tile).contains(&nz), "tile budget exceeded: {nz}");
            expect_start += nz;
        }
        assert_eq!(expect_start, depth, "grid does not cover the depth");
    });
}

/// Halo reads never exceed the volume bounds, always contain their
/// tile, and never add more than `radius` slices per side.
#[test]
fn prop_halo_ranges_stay_in_bounds() {
    for_all_seeds(40, |seed| {
        let mut rng = Rng64::new(seed ^ 0x4A10);
        let depth = 1 + rng.below(120) as usize;
        let tile = [1usize, 2, 3, 5, 17][rng.below(5) as usize];
        let radius = rng.below(3) as usize;
        for (z0, nz) in tile_ranges(depth, tile) {
            let (hz0, hnz) = halo_range(z0, nz, depth, radius);
            assert!(hz0 <= z0, "halo must start at or before its tile");
            assert!(hz0 + hnz >= z0 + nz, "halo must contain its tile");
            assert!(hz0 + hnz <= depth, "halo read past the volume");
            assert!(z0 - hz0 <= radius, "lower halo wider than the radius");
            assert!((hz0 + hnz) - (z0 + nz) <= radius, "upper halo wider than the radius");
        }
    });
}

fn random_volume(rng: &mut Rng64) -> VoxelVolume {
    let gw = 3 + rng.below(8) as usize;
    let gh = 3 + rng.below(8) as usize;
    let d = 2 + rng.below(7) as usize;
    let n = gw * gh * d;
    let voxels: Vec<u8> = (0..n)
        .map(|_| {
            let mu = [25.0, 95.0, 165.0, 235.0][rng.below(4) as usize];
            rng.gauss(mu, 4.0).clamp(0.0, 255.0) as u8
        })
        .collect();
    let mut mask = vec![1u8; n];
    for m in mask.iter_mut() {
        if rng.below(5) == 0 {
            *m = 0;
        }
    }
    VoxelVolume::from_voxels(gw, gh, d, voxels).with_mask(mask)
}

/// Masked voxels keep sentinel label 0 on every streamed engine, for
/// every tile size — and the label stream always covers the volume.
#[test]
fn prop_streamed_masked_labels_always_sentinel() {
    for_all_seeds(4, |seed| {
        let mut rng = Rng64::new(seed ^ 0x5EA7);
        let vol = random_volume(&mut rng);
        let mask = vol.mask.clone().unwrap();
        let params = FcmParams {
            max_iters: 12,
            seed,
            ..FcmParams::default()
        };
        for tile in [1usize, 2, 3, 5, 17] {
            for backend in [Backend::Parallel, Backend::Histogram] {
                let mut src = vol.clone();
                let mut sink = Vec::new();
                run_streamed(
                    &mut src,
                    &mut sink,
                    &params,
                    &StreamOpts {
                        backend,
                        threads: 2,
                        tile_slices: tile,
                    },
                )
                .unwrap();
                assert_eq!(sink.len(), vol.len(), "{backend:?} tile {tile}");
                for (i, (&l, &mk)) in sink.iter().zip(&mask).enumerate() {
                    if mk == 0 {
                        assert_eq!(l, 0, "{backend:?} tile {tile}: voxel {i}");
                    }
                }
            }
            // The halo-streamed spatial path honors the same contract.
            let mut src = vol.clone();
            let mut sink = Vec::new();
            run_streamed_spatial(
                &mut src,
                &mut sink,
                &params,
                &SpatialParams::default(),
                &StreamOpts {
                    backend: Backend::Parallel,
                    threads: 2,
                    tile_slices: tile,
                },
            )
            .unwrap();
            assert_eq!(sink.len(), vol.len(), "spatial tile {tile}");
            for (i, (&l, &mk)) in sink.iter().zip(&mask).enumerate() {
                if mk == 0 {
                    assert_eq!(l, 0, "spatial tile {tile}: voxel {i}");
                }
            }
        }
    });
}

/// The RVOL header parser rejects malformed files with clean errors —
/// never panics, and truncation surfaces the typed counts.
#[test]
fn prop_rvol_parser_rejects_corruption_cleanly() {
    use repro::image::volume::TruncatedRaster;
    // Truncated body: every proper prefix of a valid file fails to
    // parse (and never panics); the header-complete prefixes fail with
    // the typed truncation error.
    let vol = VoxelVolume::from_voxels(3, 2, 2, (0..12).map(|i| i as u8 * 9).collect());
    let mut buf = Vec::new();
    volume::write_raw_to(&vol, &mut buf).unwrap();
    let header_len = buf.len() - vol.len();
    for cut in 0..buf.len() {
        let err = volume::parse_raw(&buf[..cut]).unwrap_err();
        if cut >= header_len {
            let t = err
                .downcast_ref::<TruncatedRaster>()
                .unwrap_or_else(|| panic!("cut {cut}: expected the typed truncation error"));
            assert_eq!(t.needed, 12);
            assert_eq!(t.have, cut - header_len);
        }
    }
    // Junk magic, oversize dims, bad/missing maxval lines.
    let malformed: [&[u8]; 13] = [
        b"VOXL\n2 2 2\n255\n\0\0\0\0\0\0\0\0",
        b"P5\n2 2\n255\n\0\0\0\0",
        b"",
        b"RVOL",
        b"RVOL\n2\n",
        b"RVOL\n2 2\n255\n",
        b"RVOL\n-1 2 2\n255\n",
        b"RVOL\n2.5 2 2\n255\n",
        b"RVOL\n99999999999999999999 2 2\n255\n",
        b"RVOL\n4294967295 4294967295 4294967295\n255\n",
        b"RVOL\n2 2 2\n", // missing maxval line entirely
        b"RVOL\n2 2 2\n65535\n\0\0\0\0\0\0\0\0",
        b"RVOL\n2 2 2\nmax\n\0\0\0\0\0\0\0\0",
    ];
    for bad in malformed {
        assert!(
            volume::parse_raw(bad).is_err(),
            "accepted malformed header: {:?}",
            String::from_utf8_lossy(&bad[..bad.len().min(24)])
        );
    }
    // The streaming reader applies the same rules at open.
    let dir = std::env::temp_dir().join(format!("prop_rvol_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("bad.rvol");
    std::fs::write(&p, b"RVOL\n4 4 4\n255\nshort").unwrap();
    let err = repro::image::volume::stream::RvolReader::open(&p).unwrap_err();
    let t = err.downcast_ref::<TruncatedRaster>().expect("typed at open");
    assert_eq!(t.needed, 64);
    assert_eq!(t.have, 5);
    std::fs::remove_dir_all(&dir).unwrap();
}

fn assert_partial_bits(a: &repro::fcm::engine::fused::PassPartial, b: &repro::fcm::engine::fused::PassPartial, what: &str) {
    let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a.num), bits(&b.num), "{what}: num bits");
    assert_eq!(bits(&a.den), bits(&b.den), "{what}: den bits");
    assert_eq!(a.jm.to_bits(), b.jm.to_bits(), "{what}: jm bits");
    assert_eq!(a.delta.to_bits(), b.delta.to_bits(), "{what}: delta bits");
}

/// The per-iteration LUT path stores and accumulates exactly what the
/// direct path would, on both integer domains, for the m=2 fast path
/// and the powf path — including masked pixels and an exact
/// center-collision singularity.
#[test]
fn prop_fused_lut_is_bit_identical_to_direct() {
    use repro::fcm::engine::fused::{
        fused_chunk_scalar, fused_chunk_scalar_ctx, FusedCtx, IntensityDomain,
    };
    for_all_seeds(5, |seed| {
        let mut rng = Rng64::new(seed ^ 0x1007);
        for (domain, levels) in [(IntensityDomain::U8, 256usize), (IntensityDomain::U16, 1 << 16)]
        {
            for m in [2.0f64, 2.5] {
                let n = 300 + rng.below(1200) as usize;
                let c = 2 + rng.below(4) as usize;
                let x: Vec<f32> = (0..n).map(|_| rng.below(levels as u64) as f32).collect();
                let w: Vec<f32> = (0..n)
                    .map(|_| if rng.below(8) == 0 { 0.0 } else { 1.0 })
                    .collect();
                let u_old = repro::fcm::init_membership_masked(c, &w, seed);
                let mut centers: Vec<f32> =
                    (0..c).map(|_| rng.uniform(0.0, (levels - 1) as f32)).collect();
                centers[0] = x[0]; // exact collision: the singularity split
                // Pass `levels` as the workload so the build gate opens
                // (the gate is performance-only; results are identical).
                let ctx = FusedCtx::build(domain, &centers, m, levels).expect("ctx");
                let mut u_direct = vec![0f32; c * n];
                let p_direct = {
                    let mut rows: Vec<&mut [f32]> = u_direct.chunks_mut(n).collect();
                    fused_chunk_scalar(&x, &w, &u_old, n, &centers, m, 0, &mut rows)
                };
                let mut u_lut = vec![0f32; c * n];
                let p_lut = {
                    let mut rows: Vec<&mut [f32]> = u_lut.chunks_mut(n).collect();
                    fused_chunk_scalar_ctx(&ctx, &x, &w, &u_old, n, 0, &mut rows)
                };
                assert_eq!(u_lut, u_direct, "{domain:?} m={m}: LUT memberships drifted");
                assert_partial_bits(&p_lut, &p_direct, &format!("{domain:?} m={m}"));
            }
        }
    });
}

/// The vector kernel equals the scalar kernel bit-for-bit for every
/// chunk length and offset — ragged tails land in the same lane slots
/// the scalar kernel uses, so the lane fold sees identical addends.
#[test]
fn prop_simd_ragged_tails_reduce_identically_to_scalar() {
    use repro::fcm::engine::fused::{fused_chunk_scalar, fused_chunk_simd};
    for_all_seeds(12, |seed| {
        let mut rng = Rng64::new(seed ^ 0x51D3);
        let n = 2 + rng.below(530) as usize;
        let c = 2 + rng.below(4) as usize;
        let x = random_intensities(&mut rng, n);
        let w: Vec<f32> = (0..n)
            .map(|_| if rng.below(6) == 0 { 0.0 } else { 1.0 })
            .collect();
        let u_old = repro::fcm::init_membership_masked(c, &w, seed);
        let centers: Vec<f32> = (0..c).map(|_| rng.uniform(5.0, 250.0)).collect();
        let start = rng.below(n as u64) as usize;
        for m in [2.0f64, 2.5] {
            let mut u_s = vec![0f32; c * n];
            let p_s = {
                let mut rows: Vec<&mut [f32]> =
                    u_s.chunks_mut(n).map(|r| &mut r[start..]).collect();
                fused_chunk_scalar(&x, &w, &u_old, n, &centers, m, start, &mut rows)
            };
            let mut u_v = vec![0f32; c * n];
            let p_v = {
                let mut rows: Vec<&mut [f32]> =
                    u_v.chunks_mut(n).map(|r| &mut r[start..]).collect();
                fused_chunk_simd(&x, &w, &u_old, n, &centers, m, start, &mut rows)
            };
            let Some(p_v) = p_v else {
                return; // no AVX on this host: nothing to compare
            };
            assert_eq!(u_v, u_s, "m={m} start={start}: SIMD memberships drifted");
            assert_partial_bits(&p_v, &p_s, &format!("m={m} start={start} len={}", n - start));
        }
    });
}

#[test]
fn prop_skullstrip_mask_is_subset_of_threshold() {
    for_all_seeds(6, |seed| {
        let s = repro::phantom::generate_slice(&repro::phantom::PhantomConfig {
            with_skull: true,
            seed,
            ..Default::default()
        });
        let p = repro::phantom::skullstrip::StripParams::default();
        let (stripped, mask) = repro::phantom::skullstrip::strip(&s.image, &p);
        assert_eq!(mask.len(), s.image.len());
        // Everything outside the mask is black; the mask is one connected
        // region (already covered by unit tests) of plausible brain size.
        let kept = mask.iter().filter(|&&b| b).count();
        assert!(kept > s.image.len() / 20, "mask too small: {kept}");
        assert!(kept < s.image.len() / 2, "mask too large: {kept}");
        for (i, &keep) in mask.iter().enumerate() {
            if !keep {
                assert_eq!(stripped.pixels[i], 0);
            }
        }
    });
}
