//! Property-based tests (in-tree harness; the offline build has no
//! proptest). Each property runs against many seeded random cases via
//! Rng64; failures print the seed for deterministic reproduction.

use repro::eval::{dice_per_class, Confusion};
use repro::fcm::{self, FcmParams};
use repro::image::{pgm, GrayImage};
use repro::util::Rng64;

/// Run `f` for `cases` seeds, reporting the failing seed.
fn for_all_seeds(cases: u64, f: impl Fn(u64)) {
    for seed in 0..cases {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(seed)));
        if result.is_err() {
            panic!("property failed for seed {seed}");
        }
    }
}

fn random_intensities(rng: &mut Rng64, n: usize) -> Vec<f32> {
    // Mixture of 2-5 modes with random spreads — realistic FCM inputs.
    let k = 2 + (rng.below(4) as usize);
    let mus: Vec<f32> = (0..k).map(|_| rng.uniform(5.0, 250.0)).collect();
    (0..n)
        .map(|_| {
            let j = rng.below(k as u64) as usize;
            let sigma = rng.uniform(1.0, 12.0);
            rng.gauss(mus[j], sigma).clamp(0.0, 255.0)
        })
        .collect()
}

#[test]
fn prop_sequential_membership_rows_always_sum_to_one() {
    for_all_seeds(20, |seed| {
        let mut rng = Rng64::new(seed);
        let n = 200 + rng.below(2000) as usize;
        let c = 2 + rng.below(5) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: c,
                max_iters: 20,
                seed,
                ..Default::default()
            },
        );
        for i in 0..n {
            let s: f32 = (0..c).map(|j| run.u[j * n + i]).sum();
            assert!((s - 1.0).abs() < 1e-3, "pixel {i} sums to {s}");
            for j in 0..c {
                let u = run.u[j * n + i];
                assert!((0.0..=1.0 + 1e-5).contains(&u), "u[{j},{i}]={u}");
            }
        }
    });
}

#[test]
fn prop_sequential_objective_never_increases() {
    for_all_seeds(15, |seed| {
        let mut rng = Rng64::new(seed ^ 0xABCD);
        let n = 500 + rng.below(1500) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: 3,
                max_iters: 30,
                seed,
                ..Default::default()
            },
        );
        for pair in run.jm_history.windows(2) {
            assert!(pair[1] <= pair[0] * (1.0 + 1e-9), "{:?}", run.jm_history);
        }
    });
}

#[test]
fn prop_labels_in_range_and_centers_in_data_hull() {
    for_all_seeds(15, |seed| {
        let mut rng = Rng64::new(seed ^ 0x1234);
        let n = 300 + rng.below(1000) as usize;
        let c = 2 + rng.below(4) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: c,
                max_iters: 40,
                seed,
                ..Default::default()
            },
        );
        let (lo, hi) = x
            .iter()
            .fold((f32::INFINITY, f32::NEG_INFINITY), |(l, h), &v| {
                (l.min(v), h.max(v))
            });
        assert!(run.labels.iter().all(|&l| (l as usize) < c));
        for &v in &run.centers {
            assert!(v >= lo - 1.0 && v <= hi + 1.0, "center {v} outside [{lo},{hi}]");
        }
    });
}

#[test]
fn prop_defuzzify_picks_argmax() {
    for_all_seeds(25, |seed| {
        let mut rng = Rng64::new(seed ^ 0x77);
        let n = 1 + rng.below(200) as usize;
        let c = 2 + rng.below(5) as usize;
        let u: Vec<f32> = (0..c * n).map(|_| rng.next_f32()).collect();
        let labels = fcm::defuzzify(&u, c, n);
        for i in 0..n {
            let li = labels[i] as usize;
            for j in 0..c {
                assert!(u[li * n + i] >= u[j * n + i] || li == j);
            }
        }
    });
}

#[test]
fn prop_brfcm_lut_consistency_and_agreement() {
    for_all_seeds(8, |seed| {
        let mut rng = Rng64::new(seed ^ 0xBEEF);
        let n = 4000 + rng.below(8000) as usize;
        let px: Vec<u8> = random_intensities(&mut rng, n)
            .into_iter()
            .map(|v| v as u8)
            .collect();
        let br = fcm::brfcm::run_on_pixels(&px, &FcmParams { seed, ..Default::default() });
        for (i, &p) in px.iter().enumerate() {
            assert_eq!(br.labels[i], br.label_lut[p as usize]);
        }
    });
}

#[test]
fn prop_dice_bounds_and_symmetry() {
    for_all_seeds(30, |seed| {
        let mut rng = Rng64::new(seed ^ 0xD1CE);
        let n = 1 + rng.below(500) as usize;
        let a: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
        let b: Vec<u8> = (0..n).map(|_| rng.below(4) as u8).collect();
        let dab = dice_per_class(&a, &b, 4);
        let dba = dice_per_class(&b, &a, 4);
        for (x, y) in dab.iter().zip(&dba) {
            assert!((x - y).abs() < 1e-12, "DSC not symmetric");
            assert!((0.0..=1.0).contains(x));
        }
        // Self-similarity is exactly 1.
        assert!(dice_per_class(&a, &a, 4).iter().all(|&d| d == 1.0));
    });
}

#[test]
fn prop_confusion_row_sums_match_truth_counts() {
    for_all_seeds(20, |seed| {
        let mut rng = Rng64::new(seed ^ 0xC0DE);
        let n = 1 + rng.below(400) as usize;
        let truth: Vec<u8> = (0..n).map(|_| rng.below(3) as u8).collect();
        let pred: Vec<u8> = (0..n).map(|_| rng.below(3) as u8).collect();
        let c = Confusion::new(&pred, &truth, 3);
        for t in 0..3usize {
            let row: u64 = (0..3).map(|p| c.at(t, p)).sum();
            let count = truth.iter().filter(|&&l| l == t as u8).count() as u64;
            assert_eq!(row, count);
        }
        assert_eq!(c.total() as usize, n);
    });
}

#[test]
fn prop_pgm_roundtrip_random_images() {
    for_all_seeds(20, |seed| {
        let mut rng = Rng64::new(seed ^ 0x9931);
        let w = 1 + rng.below(64) as usize;
        let h = 1 + rng.below(64) as usize;
        let px: Vec<u8> = (0..w * h).map(|_| rng.below(256) as u8).collect();
        let img = GrayImage::from_pixels(w, h, px);
        let mut buf = Vec::new();
        pgm::write_to(&img, &mut buf).unwrap();
        assert_eq!(pgm::parse(&buf).unwrap(), img);
    });
}

#[test]
fn prop_canonical_relabel_preserves_partition() {
    for_all_seeds(15, |seed| {
        let mut rng = Rng64::new(seed ^ 0x5150);
        let n = 100 + rng.below(400) as usize;
        let x = random_intensities(&mut rng, n);
        let w = vec![1.0; n];
        let mut run = fcm::sequential::run(
            &x,
            &w,
            &FcmParams {
                clusters: 3,
                max_iters: 25,
                seed,
                ..Default::default()
            },
        );
        let before: std::collections::HashMap<u8, usize> =
            run.labels.iter().fold(Default::default(), |mut m, &l| {
                *m.entry(l).or_default() += 1;
                m
            });
        fcm::canonical_relabel(&mut run);
        // Partition sizes are preserved as a multiset.
        let mut a: Vec<usize> = before.values().copied().collect();
        let after: std::collections::HashMap<u8, usize> =
            run.labels.iter().fold(Default::default(), |mut m, &l| {
                *m.entry(l).or_default() += 1;
                m
            });
        let mut b: Vec<usize> = after.values().copied().collect();
        a.sort();
        b.sort();
        assert_eq!(a, b);
        // Centers ascending.
        assert!(run.centers.windows(2).all(|p| p[0] <= p[1]));
    });
}

#[test]
fn prop_skullstrip_mask_is_subset_of_threshold() {
    for_all_seeds(6, |seed| {
        let s = repro::phantom::generate_slice(&repro::phantom::PhantomConfig {
            with_skull: true,
            seed,
            ..Default::default()
        });
        let p = repro::phantom::skullstrip::StripParams::default();
        let (stripped, mask) = repro::phantom::skullstrip::strip(&s.image, &p);
        assert_eq!(mask.len(), s.image.len());
        // Everything outside the mask is black; the mask is one connected
        // region (already covered by unit tests) of plausible brain size.
        let kept = mask.iter().filter(|&&b| b).count();
        assert!(kept > s.image.len() / 20, "mask too small: {kept}");
        assert!(kept < s.image.len() / 2, "mask too large: {kept}");
        for (i, &keep) in mask.iter().enumerate() {
            if !keep {
                assert_eq!(stripped.pixels[i], 0);
            }
        }
    });
}
