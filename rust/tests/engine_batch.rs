//! Integration tests for the persistent pool + batched multi-image
//! engine path (PR 2's tentpole contracts):
//!
//! * `engine::parallel` performs ZERO thread spawns after pool
//!   construction — the pool's spawn counter never moves across runs;
//! * `engine::batch::run_batch` is bit-identical to per-image
//!   `engine::run` for every thread count and batch composition.

use repro::fcm::engine::{batch, parallel, pool};
use repro::fcm::{Backend, EngineOpts, FcmParams};
use repro::util::Rng64;

fn synth(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Rng64::new(seed);
    let x = (0..n)
        .map(|i| {
            let mu = [25.0, 95.0, 160.0, 225.0][i % 4];
            rng.gauss(mu, 5.0).clamp(0.0, 255.0)
        })
        .collect();
    (x, vec![1.0; n])
}

fn opts(threads: usize) -> EngineOpts {
    EngineOpts {
        backend: Backend::Parallel,
        threads,
        chunk: 2048,
    }
}

#[test]
fn parallel_engine_never_spawns_after_pool_construction() {
    // Use a lane count no other test touches so the global pool is ours.
    let threads = 5;
    let pool = pool::global(threads);
    let base = pool.spawn_count();
    assert_eq!(base, threads - 1, "lanes - 1 OS threads at construction");

    let (x, w) = synth(20_000, 1);
    let params = FcmParams::default();
    for seed in 0..3 {
        let u0 = repro::fcm::init_membership(params.clusters, x.len(), seed);
        let run = parallel::run_from(&x, &w, u0, &params, &opts(threads));
        assert!(run.iterations > 1, "want a multi-iteration run");
    }
    assert_eq!(
        pool.spawn_count(),
        base,
        "parallel engine must dispatch onto the persistent pool, never spawn"
    );
}

#[test]
fn batched_runs_never_spawn_either() {
    let threads = 5;
    let pool = pool::global(threads);
    let base = pool.spawn_count();
    let imgs: Vec<(Vec<f32>, Vec<f32>)> = (0..3).map(|s| synth(4_000, s + 50)).collect();
    let inputs: Vec<batch::BatchInput> = imgs.iter().map(|(x, w)| (&x[..], &w[..])).collect();
    let runs = batch::run_batch(&inputs, &FcmParams::default(), &opts(threads));
    assert_eq!(runs.len(), 3);
    assert_eq!(pool.spawn_count(), base);
}

#[test]
fn run_batch_bit_identical_to_solo_runs_for_every_thread_count() {
    let imgs: Vec<(Vec<f32>, Vec<f32>)> = (0..4).map(|s| synth(8_000, s + 10)).collect();
    let inputs: Vec<batch::BatchInput> = imgs.iter().map(|(x, w)| (&x[..], &w[..])).collect();
    let params = FcmParams::default();
    for threads in [1usize, 2, 8] {
        let batched = batch::run_batch(&inputs, &params, &opts(threads));
        for (i, (run, &(x, w))) in batched.iter().zip(&inputs).enumerate() {
            let solo = parallel::run(x, w, &params, &opts(threads));
            assert_eq!(run.centers, solo.centers, "threads={threads} image={i}");
            assert_eq!(run.u, solo.u, "threads={threads} image={i}");
            assert_eq!(run.labels, solo.labels, "threads={threads} image={i}");
            assert_eq!(run.iterations, solo.iterations, "threads={threads} image={i}");
            assert_eq!(run.jm_history, solo.jm_history, "threads={threads} image={i}");
        }
    }
}

#[test]
fn run_batch_is_thread_count_invariant() {
    let imgs: Vec<(Vec<f32>, Vec<f32>)> = (0..3).map(|s| synth(6_000, s + 30)).collect();
    let inputs: Vec<batch::BatchInput> = imgs.iter().map(|(x, w)| (&x[..], &w[..])).collect();
    let params = FcmParams::default();
    let r1 = batch::run_batch(&inputs, &params, &opts(1));
    let r4 = batch::run_batch(&inputs, &params, &opts(4));
    for (a, b) in r1.iter().zip(&r4) {
        assert_eq!(a.centers, b.centers);
        assert_eq!(a.u, b.u);
        assert_eq!(a.jm_history, b.jm_history);
    }
}

#[test]
fn early_convergers_freeze_while_batch_continues() {
    // A uniform image converges almost immediately; a 4-mode image takes
    // many iterations. Batched together, each must report exactly its
    // solo iteration count.
    let uniform: (Vec<f32>, Vec<f32>) = (vec![128.0; 4_000], vec![1.0; 4_000]);
    let (hx, hw) = synth(8_000, 77);
    let params = FcmParams {
        clusters: 2,
        ..Default::default()
    };
    let inputs: Vec<batch::BatchInput> =
        vec![(&uniform.0[..], &uniform.1[..]), (&hx[..], &hw[..])];
    let batched = batch::run_batch(&inputs, &params, &opts(2));
    let solo_uniform = parallel::run(&uniform.0, &uniform.1, &params, &opts(2));
    let solo_hard = parallel::run(&hx, &hw, &params, &opts(2));
    assert_eq!(batched[0].iterations, solo_uniform.iterations);
    assert_eq!(batched[1].iterations, solo_hard.iterations);
    assert!(
        batched[0].iterations < batched[1].iterations,
        "test premise: the uniform image converges first ({} vs {})",
        batched[0].iterations,
        batched[1].iterations
    );
    assert_eq!(batched[0].centers, solo_uniform.centers);
    assert_eq!(batched[1].centers, solo_hard.centers);
}

#[test]
fn engine_level_dispatch_batches_every_backend() {
    // engine::run_batch must equal per-image engine::run for every host
    // backend (parallel takes the interleaved path, the others loop).
    let imgs: Vec<(Vec<f32>, Vec<f32>)> = (0..2)
        .map(|s| {
            let (x, w) = synth(3_000, s + 90);
            // Integral grey levels so the histogram fast path applies.
            (x.into_iter().map(|v| v.round()).collect(), w)
        })
        .collect();
    let inputs: Vec<batch::BatchInput> = imgs.iter().map(|(x, w)| (&x[..], &w[..])).collect();
    let params = FcmParams::default();
    for backend in [Backend::Sequential, Backend::Parallel, Backend::Histogram] {
        let o = EngineOpts {
            backend,
            threads: 2,
            chunk: 2048,
        };
        let batched = repro::fcm::engine::run_batch(&inputs, &params, &o);
        for (run, &(x, w)) in batched.iter().zip(&inputs) {
            let solo = repro::fcm::engine::run(x, w, &params, &o);
            assert_eq!(run.labels, solo.labels, "{backend:?}");
            assert_eq!(run.centers, solo.centers, "{backend:?}");
            assert_eq!(run.iterations, solo.iterations, "{backend:?}");
        }
    }
}
