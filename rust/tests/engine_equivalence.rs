//! Equivalence suite for the host engine backends: the parallel and
//! histogram engines must reproduce the sequential baseline from
//! identical initial memberships (centers within 1e-3, identical labels
//! after canonical relabeling, DSC >= 0.999), and the parallel engine
//! must be bit-identical across thread counts.

use repro::config::Config;
use repro::coordinator::{Engine, Service};
use repro::eval::dice_per_class;
use repro::fcm::{
    canonical_relabel, engine, init_membership, sequential, Backend, EngineOpts, FcmParams,
};
use repro::image::FeatureVector;
use repro::phantom::{generate_slice, PhantomConfig};

fn slice_features(seed: u64) -> FeatureVector {
    let s = generate_slice(&PhantomConfig {
        seed,
        ..PhantomConfig::default()
    });
    FeatureVector::from_image(&s.image)
}

fn opts(backend: Backend, threads: usize) -> EngineOpts {
    EngineOpts {
        backend,
        threads,
        chunk: 4096,
    }
}

/// centers within 1e-3, identical labels, mean DSC >= 0.999.
fn assert_equivalent(name: &str, a: &repro::fcm::FcmRun, b: &repro::fcm::FcmRun, clusters: u8) {
    for (x, y) in a.centers.iter().zip(&b.centers) {
        assert!((x - y).abs() < 1e-3, "{name}: centers {:?} vs {:?}", a.centers, b.centers);
    }
    let dsc = dice_per_class(&a.labels, &b.labels, clusters);
    let mean = dsc.iter().sum::<f64>() / clusters as f64;
    assert!(mean >= 0.999, "{name}: DSC {dsc:?}");
    assert_eq!(a.labels, b.labels, "{name}: labels diverged");
}

#[test]
fn parallel_engine_matches_sequential_on_phantom() {
    let fv = slice_features(1);
    let params = FcmParams::default();
    let u0 = init_membership(params.clusters, fv.x.len(), params.seed);
    let mut seq = sequential::run_from(&fv.x, &fv.w, u0.clone(), &params);
    let mut par = engine::run_from(&fv.x, &fv.w, u0, &params, &opts(Backend::Parallel, 0));
    canonical_relabel(&mut seq);
    canonical_relabel(&mut par);
    assert!(seq.converged && par.converged);
    assert_equivalent("parallel", &par, &seq, 4);
}

#[test]
fn histogram_engine_matches_sequential_on_phantom() {
    let fv = slice_features(2);
    let params = FcmParams::default();
    let u0 = init_membership(params.clusters, fv.x.len(), params.seed);
    let mut seq = sequential::run_from(&fv.x, &fv.w, u0.clone(), &params);
    let mut hist = engine::run_from(&fv.x, &fv.w, u0, &params, &opts(Backend::Histogram, 1));
    canonical_relabel(&mut seq);
    canonical_relabel(&mut hist);
    assert!(seq.converged && hist.converged);
    assert_equivalent("histogram", &hist, &seq, 4);
}

#[test]
fn parallel_bit_identical_for_1_2_8_workers() {
    let fv = slice_features(3);
    let params = FcmParams::default();
    let u0 = init_membership(params.clusters, fv.x.len(), 11);
    let runs: Vec<_> = [1usize, 2, 8]
        .iter()
        .map(|&t| engine::run_from(&fv.x, &fv.w, u0.clone(), &params, &opts(Backend::Parallel, t)))
        .collect();
    for r in &runs[1..] {
        // Bit-identical: compare the raw f32 bit patterns, not with an
        // epsilon — this is the deterministic-reduction contract.
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&runs[0].centers), bits(&r.centers), "centers differ");
        assert_eq!(bits(&runs[0].u), bits(&r.u), "memberships differ");
        assert_eq!(runs[0].labels, r.labels);
        assert_eq!(runs[0].iterations, r.iterations);
        assert_eq!(runs[0].jm_history, r.jm_history);
    }
}

#[test]
fn histogram_engine_matches_parallel_on_u16_features() {
    // 16-bit intensities through the flat in-memory API: `domain`
    // classifies the feature vector as U16 and the histogram engine
    // runs 65 536 bins. From a shared u0, on well-separated integer
    // data (band gaps ~15k, jitter < 900) it must land on exactly the
    // slab engine's canonical labels, with centers tight on the
    // 0..65535 scale.
    let bands = [5000.0f32, 21000.0, 40000.0, 58000.0];
    let x: Vec<f32> = (0..4096u64)
        .map(|i| bands[(i % 4) as usize] + ((i * 2654435761) % 900) as f32)
        .collect();
    let w = vec![1.0f32; x.len()];
    let params = FcmParams::default();
    let u0 = init_membership(params.clusters, x.len(), params.seed);
    let mut par = engine::run_from(&x, &w, u0.clone(), &params, &opts(Backend::Parallel, 2));
    let mut hist = engine::run_from(&x, &w, u0, &params, &opts(Backend::Histogram, 1));
    canonical_relabel(&mut par);
    canonical_relabel(&mut hist);
    assert!(par.converged && hist.converged);
    assert_eq!(hist.labels, par.labels, "u16 labels diverged from the slab engine");
    for (a, b) in hist.centers.iter().zip(&par.centers) {
        // ~2e-5 of the intensity range: binning is exact on integer
        // data, only the bin-averaged u0 perturbs the trajectory.
        assert!((a - b).abs() < 1.5, "{:?} vs {:?}", hist.centers, par.centers);
    }
}

#[test]
fn chunk_size_changes_stay_within_tolerance() {
    // Chunking changes summation order (fp rounding), not semantics.
    let fv = slice_features(4);
    let params = FcmParams::default();
    let u0 = init_membership(params.clusters, fv.x.len(), 5);
    let mut a = engine::run_from(
        &fv.x,
        &fv.w,
        u0.clone(),
        &params,
        &EngineOpts {
            backend: Backend::Parallel,
            threads: 2,
            chunk: 1024,
        },
    );
    let mut b = engine::run_from(
        &fv.x,
        &fv.w,
        u0,
        &params,
        &EngineOpts {
            backend: Backend::Parallel,
            threads: 2,
            chunk: 16384,
        },
    );
    canonical_relabel(&mut a);
    canonical_relabel(&mut b);
    assert_equivalent("chunk-size", &a, &b, 4);
}

#[test]
fn engines_agree_through_the_service() {
    // Route Parallel and Histogram jobs through the coordinator and check
    // they converge to the sequential ticket's centers.
    let mut cfg = Config::new();
    cfg.service.workers = 2;
    let service = Service::start(&cfg).unwrap();
    let params = FcmParams::default();
    let fv = slice_features(6);
    let mut results = Vec::new();
    for eng in [Engine::Sequential, Engine::Parallel, Engine::Histogram] {
        let t = service.submit(fv.clone(), params, eng).unwrap();
        results.push((eng, t.wait().unwrap()));
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.failed, 0);
    let base = &results[0].1;
    for (eng, r) in &results {
        assert!(r.converged, "{eng:?} did not converge");
        // Canonical labels: ascending centers.
        assert!(r.centers.windows(2).all(|w| w[0] <= w[1]), "{eng:?}");
        for (a, b) in r.centers.iter().zip(&base.centers) {
            assert!((a - b).abs() < 0.1, "{eng:?}: {:?} vs {:?}", r.centers, base.centers);
        }
        let agree = r.labels.iter().zip(&base.labels).filter(|(a, b)| a == b).count();
        assert!(
            agree as f64 / base.labels.len() as f64 > 0.999,
            "{eng:?} agreement {agree}/{}",
            base.labels.len()
        );
    }
}

#[test]
fn histogram_weighted_features_match_brfcm_module() {
    // The engine's histogram backend and the legacy brfcm module are the
    // same math; pin them against each other on a real slice.
    let s = generate_slice(&PhantomConfig {
        seed: 7,
        ..PhantomConfig::default()
    });
    let params = FcmParams::default();
    let mut br = repro::fcm::brfcm::run_on_pixels(&s.image.pixels, &params);
    canonical_relabel(&mut br.bin_run);
    let br = repro::fcm::brfcm::finish(&s.image.pixels, br.bin_run);

    let fv = FeatureVector::from_image(&s.image);
    let mut hist = engine::run(&fv.x, &fv.w, &params, &opts(Backend::Histogram, 1));
    canonical_relabel(&mut hist);

    for (a, b) in hist.centers.iter().zip(&br.bin_run.centers) {
        assert!((a - b).abs() < 0.5, "{:?} vs {:?}", hist.centers, br.bin_run.centers);
    }
    let agree = hist.labels.iter().zip(&br.labels).filter(|(x, y)| x == y).count();
    assert!(agree as f64 / br.labels.len() as f64 > 0.999);
}

#[test]
fn masked_padding_preserved_by_all_backends() {
    let fv = slice_features(8);
    let padded = repro::image::pad_to(&fv, fv.len() + 1000);
    let params = FcmParams::default();
    let n = padded.len();
    for backend in [Backend::Sequential, Backend::Parallel, Backend::Histogram] {
        let run = engine::run(&padded.x, &padded.w, &params, &opts(backend, 2));
        for j in 0..params.clusters {
            for i in fv.len()..n {
                assert_eq!(run.u[j * n + i], 0.0, "{backend} leaked into padding");
            }
        }
    }
}
