//! Integration tests over the L3 coordinator: engines, batching, failure
//! injection, backpressure, and determinism.

use repro::config::Config;
use repro::coordinator::{Engine, Service};
use repro::fcm::FcmParams;
use repro::image::FeatureVector;
use repro::phantom::{generate_slice, PhantomConfig};

mod common;

fn small_cfg(workers: usize) -> Config {
    let mut cfg = Config::new();
    cfg.service.workers = workers;
    cfg.service.max_batch = 4;
    cfg
}

fn crop(n: usize, seed: u64) -> FeatureVector {
    let s = generate_slice(&PhantomConfig {
        seed,
        ..PhantomConfig::default()
    });
    FeatureVector::from_values(s.image.pixels[..n].iter().map(|&p| p as f32).collect())
}

#[test]
fn serves_all_engines() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(1)).unwrap();
    let params = FcmParams::default();
    let fv = crop(4096, 1);
    let mut results = Vec::new();
    for engine in [Engine::Device, Engine::DeviceRef, Engine::Sequential, Engine::BrFcm] {
        let t = service.submit(fv.clone(), params, engine).unwrap();
        results.push((engine, t.wait().unwrap()));
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 4);
    assert_eq!(snap.failed, 0);
    // All engines should find approximately the same centers.
    let base = &results[0].1.centers;
    for (engine, r) in &results {
        assert!(r.converged, "{engine:?} did not converge");
        for (a, b) in r.centers.iter().zip(base) {
            assert!((a - b).abs() < 4.0, "{engine:?}: {:?} vs {base:?}", r.centers);
        }
        // Canonical labels: ascending centers.
        assert!(r.centers.windows(2).all(|w| w[0] <= w[1]));
    }
}

#[test]
fn failure_injection_bad_clusters() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(1)).unwrap();
    let params = FcmParams {
        clusters: 7, // no artifact for c=7
        ..Default::default()
    };
    let t = service.submit(crop(256, 2), params, Engine::Device).unwrap();
    let err = t.wait().unwrap_err();
    assert!(format!("{err:#}").contains("no fcm_iteration artifact"));
    // A failed job must not poison the worker: the next job succeeds.
    let ok = service
        .submit(crop(256, 3), FcmParams::default(), Engine::Device)
        .unwrap();
    assert!(ok.wait().is_ok());
    let snap = service.shutdown();
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.completed, 1);
}

#[test]
fn batching_groups_same_bucket_jobs() {
    if !common::device_ready() {
        return;
    }
    let mut cfg = small_cfg(1);
    cfg.service.max_batch = 8;
    let service = Service::start(&cfg).unwrap();
    let params = FcmParams {
        max_iters: 3,
        ..Default::default()
    };
    // 8 identical-bucket jobs, 1 worker: expect far fewer batches than jobs.
    let tickets: Vec<_> = (0..8)
        .map(|i| service.submit(crop(4096, i), params, Engine::Device).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 8);
    assert!(
        snap.mean_batch_size > 1.5,
        "batching ineffective: {:?}",
        snap
    );
}

#[test]
fn mixed_buckets_still_all_served() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(2)).unwrap();
    let params = FcmParams {
        max_iters: 5,
        ..Default::default()
    };
    let mut tickets = Vec::new();
    for i in 0..6u64 {
        tickets.push(service.submit(crop(256, i), params, Engine::Device).unwrap());
        tickets.push(service.submit(crop(4096, i), params, Engine::Device).unwrap());
    }
    let mut served = 0;
    for t in tickets {
        t.wait().unwrap();
        served += 1;
    }
    assert_eq!(served, 12);
}

#[test]
fn results_deterministic_per_seed() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(2)).unwrap();
    let params = FcmParams::default();
    let a = service
        .submit(crop(4096, 7), params, Engine::Device)
        .unwrap()
        .wait()
        .unwrap();
    let b = service
        .submit(crop(4096, 7), params, Engine::Device)
        .unwrap()
        .wait()
        .unwrap();
    service.shutdown();
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.centers, b.centers);
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn shutdown_with_queued_work_drains() {
    let service = Service::start(&small_cfg(2)).unwrap();
    let params = FcmParams {
        max_iters: 2,
        ..Default::default()
    };
    let tickets: Vec<_> = (0..10)
        .map(|i| service.submit(crop(256, i), params, Engine::Sequential).unwrap())
        .collect();
    // Shut down immediately; queued jobs must still be served (drain).
    let snap = service.shutdown();
    let mut ok = 0;
    for t in tickets {
        if t.wait().is_ok() {
            ok += 1;
        }
    }
    assert_eq!(ok, 10, "{snap:?}");
}

#[test]
fn metrics_track_queue_and_service_time() {
    let service = Service::start(&small_cfg(1)).unwrap();
    let params = FcmParams::default();
    for i in 0..4 {
        service
            .submit(crop(4096, i), params, Engine::Sequential)
            .unwrap()
            .wait()
            .unwrap();
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 4);
    assert!(snap.mean_service_s > 0.0);
    assert!(snap.mean_iterations > 1.0);
}
