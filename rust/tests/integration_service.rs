//! Integration tests over the L3 coordinator: engines, batching, failure
//! injection, backpressure, and determinism.

use repro::config::Config;
use repro::coordinator::{Engine, Service, Ticket};
use repro::fcm::FcmParams;
use repro::image::FeatureVector;
use repro::phantom::{generate_slice, PhantomConfig};

mod common;

/// A long job that keeps the single worker busy while the caller
/// enqueues the jobs whose batching behavior is under test (uses the
/// Sequential engine and an odd shape, so it never co-batches with
/// them).
fn submit_blocker(service: &Service) -> Ticket {
    let params = FcmParams {
        epsilon: 0.0,
        max_iters: 40,
        ..Default::default()
    };
    service
        .submit(crop(30_001, 999), params, Engine::Sequential)
        .unwrap()
}

fn small_cfg(workers: usize) -> Config {
    let mut cfg = Config::new();
    cfg.service.workers = workers;
    cfg.service.max_batch = 4;
    cfg
}

fn crop(n: usize, seed: u64) -> FeatureVector {
    let s = generate_slice(&PhantomConfig {
        seed,
        ..PhantomConfig::default()
    });
    FeatureVector::from_values(s.image.pixels[..n].iter().map(|&p| p as f32).collect())
}

#[test]
fn serves_all_engines() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(1)).unwrap();
    let params = FcmParams::default();
    let fv = crop(4096, 1);
    let mut results = Vec::new();
    for engine in [Engine::Device, Engine::DeviceRef, Engine::Sequential, Engine::BrFcm] {
        let t = service.submit(fv.clone(), params, engine).unwrap();
        results.push((engine, t.wait().unwrap()));
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 4);
    assert_eq!(snap.failed, 0);
    // All engines should find approximately the same centers.
    let base = &results[0].1.centers;
    for (engine, r) in &results {
        assert!(r.converged, "{engine:?} did not converge");
        for (a, b) in r.centers.iter().zip(base) {
            assert!((a - b).abs() < 4.0, "{engine:?}: {:?} vs {base:?}", r.centers);
        }
        // Canonical labels: ascending centers.
        assert!(r.centers.windows(2).all(|w| w[0] <= w[1]));
    }
}

#[test]
fn failure_injection_bad_clusters() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(1)).unwrap();
    let params = FcmParams {
        clusters: 7, // no artifact for c=7
        ..Default::default()
    };
    let t = service.submit(crop(256, 2), params, Engine::Device).unwrap();
    let err = t.wait().unwrap_err();
    assert!(format!("{err:#}").contains("no fcm_iteration artifact"));
    // A failed job must not poison the worker: the next job succeeds.
    let ok = service
        .submit(crop(256, 3), FcmParams::default(), Engine::Device)
        .unwrap();
    assert!(ok.wait().is_ok());
    let snap = service.shutdown();
    assert_eq!(snap.failed, 1);
    assert_eq!(snap.completed, 1);
}

#[test]
fn batching_groups_same_bucket_jobs() {
    if !common::device_ready() {
        return;
    }
    let mut cfg = small_cfg(1);
    cfg.service.max_batch = 8;
    let service = Service::start(&cfg).unwrap();
    let params = FcmParams {
        max_iters: 3,
        ..Default::default()
    };
    // 8 identical-bucket jobs, 1 worker: expect far fewer batches than jobs.
    let tickets: Vec<_> = (0..8)
        .map(|i| service.submit(crop(4096, i), params, Engine::Device).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 8);
    assert!(
        snap.mean_batch_size > 1.5,
        "batching ineffective: {:?}",
        snap
    );
}

#[test]
fn mixed_buckets_still_all_served() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(2)).unwrap();
    let params = FcmParams {
        max_iters: 5,
        ..Default::default()
    };
    let mut tickets = Vec::new();
    for i in 0..6u64 {
        tickets.push(service.submit(crop(256, i), params, Engine::Device).unwrap());
        tickets.push(service.submit(crop(4096, i), params, Engine::Device).unwrap());
    }
    let mut served = 0;
    for t in tickets {
        t.wait().unwrap();
        served += 1;
    }
    assert_eq!(served, 12);
}

#[test]
fn results_deterministic_per_seed() {
    if !common::device_ready() {
        return;
    }
    let service = Service::start(&small_cfg(2)).unwrap();
    let params = FcmParams::default();
    let a = service
        .submit(crop(4096, 7), params, Engine::Device)
        .unwrap()
        .wait()
        .unwrap();
    let b = service
        .submit(crop(4096, 7), params, Engine::Device)
        .unwrap()
        .wait()
        .unwrap();
    service.shutdown();
    assert_eq!(a.labels, b.labels);
    assert_eq!(a.centers, b.centers);
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn same_shape_host_jobs_execute_as_one_batch() {
    // 1 worker, busy on a blocker while 4 same-shape parallel jobs
    // queue: they must come back with ONE shared batch_id, and — the
    // tentpole acceptance criterion — results bit-identical to four
    // independent engine runs.
    let mut cfg = small_cfg(1);
    cfg.service.max_batch = 8;
    let service = Service::start(&cfg).unwrap();
    let blocker = submit_blocker(&service);
    let params = FcmParams::default();
    let fvs: Vec<FeatureVector> = (0..4).map(|i| crop(4096, i)).collect();
    let tickets: Vec<_> = fvs
        .iter()
        .map(|fv| service.submit(fv.clone(), params, Engine::Parallel).unwrap())
        .collect();
    let results: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
    blocker.wait().unwrap();
    let snap = service.shutdown();

    let batch_id = results[0].batch_id;
    assert!(
        results.iter().all(|r| r.batch_id == batch_id),
        "same-shape jobs must share one batch: {:?}",
        results.iter().map(|r| r.batch_id).collect::<Vec<_>>()
    );
    let par = snap.engine_stats(Engine::Parallel).unwrap();
    assert_eq!(par.batches, 1, "one segment_batch invocation");
    assert_eq!(par.jobs, 4);

    let opts = repro::fcm::EngineOpts::default();
    for (r, fv) in results.iter().zip(&fvs) {
        let mut solo = repro::fcm::engine::run(&fv.x, &fv.w, &params, &opts);
        repro::fcm::canonical_relabel(&mut solo);
        assert_eq!(r.labels, solo.labels, "batched result diverged from solo run");
        assert_eq!(r.centers, solo.centers);
        assert_eq!(r.iterations, solo.iterations);
    }
}

#[test]
fn mixed_engine_jobs_do_not_cobatch() {
    let mut cfg = small_cfg(1);
    cfg.service.max_batch = 8;
    let service = Service::start(&cfg).unwrap();
    let blocker = submit_blocker(&service);
    let params = FcmParams::default();
    let mut tickets = Vec::new();
    for i in 0..2 {
        tickets.push((Engine::Parallel, service.submit(crop(4096, i), params, Engine::Parallel).unwrap()));
        tickets.push((Engine::Histogram, service.submit(crop(4096, i), params, Engine::Histogram).unwrap()));
    }
    let results: Vec<_> = tickets
        .into_iter()
        .map(|(e, t)| (e, t.wait().unwrap()))
        .collect();
    blocker.wait().unwrap();
    service.shutdown();
    let parallel_ids: Vec<u64> = results
        .iter()
        .filter(|(e, _)| *e == Engine::Parallel)
        .map(|(_, r)| r.batch_id)
        .collect();
    let histogram_ids: Vec<u64> = results
        .iter()
        .filter(|(e, _)| *e == Engine::Histogram)
        .map(|(_, r)| r.batch_id)
        .collect();
    assert_eq!(parallel_ids[0], parallel_ids[1], "same engine co-batches");
    assert_eq!(histogram_ids[0], histogram_ids[1], "same engine co-batches");
    assert_ne!(
        parallel_ids[0], histogram_ids[0],
        "different engines must never share a batch"
    );
}

#[test]
fn batched_results_identical_across_engine_thread_counts() {
    let params = FcmParams::default();
    let fvs: Vec<FeatureVector> = (0..3).map(|i| crop(4096, i + 20)).collect();
    let mut per_threads = Vec::new();
    for threads in [1usize, 3] {
        let mut cfg = small_cfg(1);
        cfg.service.max_batch = 8;
        cfg.engine.threads = threads;
        let service = Service::start(&cfg).unwrap();
        let blocker = submit_blocker(&service);
        let tickets: Vec<_> = fvs
            .iter()
            .map(|fv| service.submit(fv.clone(), params, Engine::Parallel).unwrap())
            .collect();
        let results: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        blocker.wait().unwrap();
        service.shutdown();
        per_threads.push(results);
    }
    for (a, b) in per_threads[0].iter().zip(&per_threads[1]) {
        assert_eq!(a.labels, b.labels, "thread count changed batched labels");
        assert_eq!(a.centers, b.centers);
        assert_eq!(a.iterations, b.iterations);
    }
}

#[test]
fn brfcm_labels_stay_aligned_under_masking() {
    // The old serve loop dropped masked pixels from the brFCM pixel
    // vector, shifting every label after the first masked position.
    // Labels must stay index-aligned: sentinel 0 where w = 0, unshifted
    // elsewhere.
    let service = Service::start(&small_cfg(1)).unwrap();
    let params = FcmParams::default();
    let fv = crop(5_000, 3);
    let padded = repro::image::pad_to(&fv, 6_000);
    let full = service
        .submit(fv, params, Engine::BrFcm)
        .unwrap()
        .wait()
        .unwrap();
    let masked = service
        .submit(padded, params, Engine::BrFcm)
        .unwrap()
        .wait()
        .unwrap();
    service.shutdown();
    assert_eq!(masked.labels.len(), 6_000, "labels must cover the submitted vec");
    assert_eq!(
        &masked.labels[..5_000],
        &full.labels[..],
        "masked submission shifted real-pixel labels"
    );
    assert!(
        masked.labels[5_000..].iter().all(|&l| l == 0),
        "masked positions must keep the sentinel label"
    );
}

#[test]
fn batch_execute_off_matches_batched_results() {
    let params = FcmParams::default();
    let fvs: Vec<FeatureVector> = (0..3).map(|i| crop(4096, i + 40)).collect();
    let run_with = |batch_execute: bool| {
        let mut cfg = small_cfg(1);
        cfg.service.max_batch = 8;
        cfg.service.batch_execute = batch_execute;
        let service = Service::start(&cfg).unwrap();
        let blocker = submit_blocker(&service);
        let tickets: Vec<_> = fvs
            .iter()
            .map(|fv| service.submit(fv.clone(), params, Engine::Parallel).unwrap())
            .collect();
        let results: Vec<_> = tickets.into_iter().map(|t| t.wait().unwrap()).collect();
        blocker.wait().unwrap();
        service.shutdown();
        results
    };
    let batched = run_with(true);
    let looped = run_with(false);
    for (a, b) in batched.iter().zip(&looped) {
        assert_eq!(a.labels, b.labels, "batched execution changed results");
        assert_eq!(a.centers, b.centers);
        assert_eq!(a.iterations, b.iterations);
    }
}

#[test]
fn shutdown_with_queued_work_drains() {
    let service = Service::start(&small_cfg(2)).unwrap();
    let params = FcmParams {
        max_iters: 2,
        ..Default::default()
    };
    let tickets: Vec<_> = (0..10)
        .map(|i| service.submit(crop(256, i), params, Engine::Sequential).unwrap())
        .collect();
    // Shut down immediately; queued jobs must still be served (drain).
    let snap = service.shutdown();
    let mut ok = 0;
    for t in tickets {
        if t.wait().is_ok() {
            ok += 1;
        }
    }
    assert_eq!(ok, 10, "{snap:?}");
}

#[test]
fn metrics_track_queue_and_service_time() {
    let service = Service::start(&small_cfg(1)).unwrap();
    let params = FcmParams::default();
    for i in 0..4 {
        service
            .submit(crop(4096, i), params, Engine::Sequential)
            .unwrap()
            .wait()
            .unwrap();
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 4);
    assert!(snap.mean_service_s > 0.0);
    assert!(snap.mean_iterations > 1.0);
}
