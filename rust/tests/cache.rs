//! Integration suite for the result cache (PR 9):
//!
//! * a cache hit is **transparent** — byte-identical labels to a cold
//!   run (and to a `--no-cache` control run) with no engine execution;
//! * single-flight: N concurrent equal-key submissions coalesce onto
//!   exactly ONE execution, with exact hit/miss/coalesce accounting;
//! * the LRU respects its byte budget and reports evictions;
//! * the file store survives a service restart and detects a flipped
//!   bit as a miss (the job re-executes and heals the entry);
//! * cancelling a coalesced waiter never cancels the flight leader;
//! * a High-priority job overtakes queued Normal jobs on the drain;
//! * the streamed digest fold adds ZERO reads to a run and reproduces
//!   the one-shot raster digest bit-for-bit;
//! * a streamed hit replays byte-identical output while bypassing
//!   admission control entirely (it holds no resident tiles).

mod common;

use repro::config::Config;
use repro::coordinator::{
    backend_for, CacheKey, CancelToken, Engine, Interrupted, OutputKind, Priority, Service,
    Snapshot, StreamVolumeJob, Ticket,
};
use repro::fcm::{EngineOpts, FcmParams};
use repro::image::volume::stream::{
    raster_digest, DigestSource, FaultPlan, FaultySource, RvolReader,
};
use repro::image::{volume, VoxelVolume};
use repro::phantom::{generate_volume, PhantomConfig};
use std::path::{Path, PathBuf};
use std::time::Duration;

fn phantom_rvol(width: usize, height: usize, depth: usize) -> VoxelVolume {
    let start = 90usize.min(181 - depth);
    generate_volume(
        &PhantomConfig {
            width,
            height,
            ..PhantomConfig::default()
        },
        start,
        start + depth,
        1,
    )
    .to_voxel_volume()
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cache_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Fixed-iteration params: epsilon unreachable, so byte-identity across
/// runs is a pure determinism check, not a convergence coincidence.
fn fast_params() -> FcmParams {
    FcmParams {
        epsilon: 0.0,
        max_iters: 6,
        ..FcmParams::default()
    }
}

fn engine_batches(snap: &Snapshot, engine: &str) -> u64 {
    snap.per_engine
        .iter()
        .find(|e| e.engine == engine)
        .map_or(0, |e| e.batches)
}

/// A slow fault-injected streamed job (uncacheable, so it never touches
/// the cache counters) that pins the sole worker while the jobs under
/// test queue up behind it.
fn blocker(service: &Service, dir: &Path, input: &Path, ms: u64) -> Ticket {
    service
        .submit_volume_streamed(
            StreamVolumeJob {
                input: input.to_path_buf(),
                mask: None,
                output: dir.join("blocker.rvol"),
                tile_slices: 1,
                prefetch: false,
                fault: Some(FaultPlan {
                    latency: Duration::from_millis(ms),
                    ..FaultPlan::default()
                }),
            },
            fast_params(),
            Engine::Histogram,
        )
        .unwrap()
}

#[test]
fn volume_hit_is_transparent_and_skips_execution() {
    let vol = phantom_rvol(21, 23, 8);
    let params = fast_params();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    let service = Service::start(&cfg).unwrap();
    let cold = service
        .submit_volume(vol.clone(), params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert!(!cold.cached, "first contact must execute");
    assert!(!cold.labels.is_empty());
    let hit = service
        .submit_volume(vol.clone(), params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert!(hit.cached);
    assert_eq!(hit.labels, cold.labels, "hit bytes must equal the cold run's");
    assert_eq!(hit.centers, cold.centers);
    assert_eq!(hit.iterations, cold.iterations);
    let snap = service.shutdown();
    assert_eq!(snap.cache_hits, 1);
    assert_eq!(snap.cache_misses, 1);
    assert_eq!(snap.coalesced_waiters, 0);
    assert_eq!(engine_batches(&snap, "parallel"), 1, "the hit ran no engine work");
    assert_eq!(snap.submitted, 2);
    assert_eq!(snap.completed, 2);

    // Control: a no-cache service produces the same bytes — the cache
    // is an optimization, never an observable behavior change.
    let mut plain_cfg = Config::new();
    plain_cfg.service.workers = 1;
    plain_cfg.cache.enabled = false;
    let plain = Service::start(&plain_cfg).unwrap();
    let r = plain
        .submit_volume(vol, params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert!(!r.cached);
    assert_eq!(r.labels, cold.labels, "--no-cache run diverged from cached bytes");
    let plain_snap = plain.shutdown();
    assert_eq!(
        plain_snap.cache_hits + plain_snap.cache_misses + plain_snap.coalesced_waiters,
        0,
        "a disabled cache touches no cache counters"
    );
    assert_eq!(engine_batches(&plain_snap, "parallel"), 1);
}

#[test]
fn single_flight_soak_runs_exactly_once() {
    // THE single-flight gate: 8 identical submissions land while the
    // sole worker is pinned, so one leads and seven coalesce — then the
    // leader's single execution answers all eight with the same bytes.
    let dir = tmp_dir("soak");
    let input = dir.join("in.rvol");
    volume::save_raw(&phantom_rvol(17, 19, 6), &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    cfg.engine.threads = common::engine_threads();
    let service = Service::start(&cfg).unwrap();
    let pin = blocker(&service, &dir, &input, 10);

    let vol = phantom_rvol(33, 35, 10);
    let params = fast_params();
    let tickets: Vec<Ticket> = (0..8)
        .map(|_| {
            service
                .submit_volume(vol.clone(), params, Engine::Parallel)
                .unwrap()
        })
        .collect();
    pin.wait().unwrap();

    let mut results = Vec::new();
    for (i, t) in tickets.into_iter().enumerate() {
        results.push(t.wait().unwrap_or_else(|e| panic!("submission {i}: {e:#}")));
    }
    assert!(!results[0].cached, "the first submission leads the flight");
    for (i, r) in results.iter().enumerate().skip(1) {
        assert!(r.cached, "submission {i} must be served from the flight");
        assert_eq!(r.labels, results[0].labels, "submission {i} bytes diverged");
        assert_eq!(r.centers, results[0].centers);
    }
    let snap = service.shutdown();
    assert_eq!(engine_batches(&snap, "parallel"), 1, "exactly ONE execution");
    assert_eq!(snap.cache_misses, 1, "one flight leader");
    assert_eq!(snap.coalesced_waiters, 7, "seven coalesced waiters");
    assert_eq!(snap.cache_hits, 0, "all equal-key submissions raced the flight");
    assert_eq!(snap.submitted, 9, "8 volume jobs + the blocker");
    assert_eq!(snap.completed, 9);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.cancelled, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn lru_eviction_respects_byte_budget_through_the_service() {
    let vol = phantom_rvol(17, 19, 6);
    let params = fast_params();
    // One cached volume result costs its label bytes + 4 bytes per
    // center + the fixed overhead (CachedResult::cost).
    let cost = 17 * 19 * 6 + params.clusters * 4 + 96;
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    cfg.cache.capacity_bytes = cost + 16; // fits exactly one entry
    let service = Service::start(&cfg).unwrap();
    let with_seed = |seed: u64| FcmParams { seed, ..params };

    // seed 1 -> insert; seed 2 -> evicts 1; seed 1 again -> miss.
    for seed in [1u64, 2, 1] {
        let r = service
            .submit_volume(vol.clone(), with_seed(seed), Engine::Parallel)
            .unwrap()
            .wait()
            .unwrap();
        assert!(!r.cached, "every run misses: the budget holds one entry");
    }
    let snap = service.shutdown();
    assert_eq!(snap.cache_misses, 3);
    assert_eq!(snap.cache_hits, 0);
    assert_eq!(snap.cache_evictions, 2, "each insert displaces the previous entry");
    assert_eq!(snap.cache_bytes, cost as u64);
    assert!(snap.cache_bytes <= cfg.cache.capacity_bytes as u64, "budget respected");
    assert_eq!(snap.cache_bytes_peak, cost as u64);
    assert_eq!(engine_batches(&snap, "parallel"), 3);
}

#[test]
fn file_store_survives_restart_and_detects_corruption() {
    let dir = tmp_dir("disk");
    let cache_dir = dir.join("cache");
    let vol = phantom_rvol(19, 17, 7);
    let params = fast_params();
    let key = CacheKey::new(
        raster_digest(19, 17, 7, 8, &vol.voxels),
        None,
        Engine::Parallel,
        &params,
        OutputKind::Volume,
    );
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    cfg.cache.dir = Some(cache_dir.display().to_string());

    let first = Service::start(&cfg).unwrap();
    let cold = first
        .submit_volume(vol.clone(), params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    let snap = first.shutdown();
    assert_eq!(snap.cache_misses, 1);
    let rfile = cache_dir.join(format!("{:016x}.rcache", key.file_digest()));
    assert!(rfile.exists(), "worker persisted the result to the cache dir");

    // A fresh service (fresh process, conceptually) hits from disk.
    let second = Service::start(&cfg).unwrap();
    let warm = second
        .submit_volume(vol.clone(), params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert!(warm.cached);
    assert_eq!(warm.labels, cold.labels, "disk hit bytes must equal the cold run's");
    let snap = second.shutdown();
    assert_eq!(snap.cache_hits, 1);
    assert_eq!(snap.cache_misses, 0);
    assert_eq!(engine_batches(&snap, "parallel"), 0, "no execution on a disk hit");

    // Flip one label bit on disk: the digest re-check refuses the
    // entry, the job re-executes, and the store heals.
    let mut bytes = std::fs::read(&rfile).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x01;
    std::fs::write(&rfile, &bytes).unwrap();
    let third = Service::start(&cfg).unwrap();
    let healed = third
        .submit_volume(vol, params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert!(!healed.cached, "a flipped bit is a miss, never wrong bytes");
    assert_eq!(healed.labels, cold.labels);
    let snap = third.shutdown();
    assert_eq!(snap.cache_misses, 1);
    assert_eq!(snap.cache_hits, 0);
    assert_eq!(engine_batches(&snap, "parallel"), 1);
    assert!(rfile.exists(), "the re-run rewrote a valid entry");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn cancelling_a_waiter_never_cancels_the_leader() {
    let dir = tmp_dir("waiter_cancel");
    let input = dir.join("in.rvol");
    volume::save_raw(&phantom_rvol(17, 19, 6), &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    let service = Service::start(&cfg).unwrap();
    let pin = blocker(&service, &dir, &input, 10);

    let vol = phantom_rvol(23, 21, 9);
    let params = fast_params();
    let submit = || {
        service
            .submit_volume(vol.clone(), params, Engine::Parallel)
            .unwrap()
    };
    let leader = submit();
    let kept = submit();
    let dropped = submit();
    let kept_too = submit();
    dropped.cancel();
    pin.wait().unwrap();

    let lead_r = leader.wait().unwrap();
    assert!(!lead_r.cached, "the leader executed despite a waiter's cancellation");
    let r1 = kept.wait().unwrap();
    let err = dropped.wait().unwrap_err();
    assert!(
        matches!(err.downcast_ref::<Interrupted>(), Some(Interrupted::Cancelled)),
        "the cancelled waiter gets the typed cancel error, got: {err:#}"
    );
    let r2 = kept_too.wait().unwrap();
    for r in [&r1, &r2] {
        assert!(r.cached);
        assert_eq!(r.labels, lead_r.labels, "surviving waiters share the leader's bytes");
    }
    let snap = service.shutdown();
    assert_eq!(snap.submitted, 5, "blocker + leader + 3 waiters");
    assert_eq!(snap.completed, 4);
    assert_eq!(snap.cancelled, 1);
    assert_eq!(snap.failed, 0);
    assert_eq!(snap.coalesced_waiters, 3);
    assert_eq!(snap.cache_misses, 1);
    assert_eq!(engine_batches(&snap, "parallel"), 1, "one execution served all survivors");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn high_priority_overtakes_queued_normal_jobs() {
    let dir = tmp_dir("priority");
    let input = dir.join("in.rvol");
    volume::save_raw(&phantom_rvol(17, 19, 6), &input).unwrap();
    let mut cfg = Config::new();
    cfg.service.workers = 1;
    // Identical volumes would coalesce instead of queueing — disable
    // the cache so all four jobs are real queue entries.
    cfg.cache.enabled = false;
    let service = Service::start(&cfg).unwrap();
    let pin = blocker(&service, &dir, &input, 10);

    let vol = phantom_rvol(21, 19, 7);
    let params = fast_params();
    let normals: Vec<Ticket> = (0..3)
        .map(|_| {
            service
                .submit_volume(vol.clone(), params, Engine::Parallel)
                .unwrap()
        })
        .collect();
    // Submitted LAST, drained FIRST.
    let high = service
        .submit_volume_with_priority(vol.clone(), params, Engine::Parallel, Priority::High)
        .unwrap();
    pin.wait().unwrap();

    let high_r = high.wait().unwrap();
    for (i, t) in normals.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert!(
            high_r.batch_id < r.batch_id,
            "High job (batch {}) must overtake Normal job {i} (batch {})",
            high_r.batch_id,
            r.batch_id
        );
    }
    let snap = service.shutdown();
    assert_eq!(snap.completed, 5);
    assert_eq!(snap.cache_hits + snap.cache_misses + snap.coalesced_waiters, 0);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn streamed_digest_fold_adds_zero_reads() {
    // The acceptance gate for "no extra I/O pass": a streamed run with
    // the DigestSource wrap performs EXACTLY the reads of a plain run,
    // emits the same labels, and its folded digest equals the one-shot
    // raster digest of the full buffer (so the in-memory and streamed
    // paths derive the same content address).
    let dir = tmp_dir("digest_reads");
    let vol = phantom_rvol(19, 21, 8);
    let input = dir.join("in.rvol");
    volume::save_raw(&vol, &input).unwrap();
    let params = fast_params();
    let backend = backend_for(Engine::Parallel, None, &EngineOpts::default()).unwrap();

    let mut plain = FaultySource::new(
        Box::new(RvolReader::open(&input).unwrap()),
        FaultPlan::default(),
        0,
    );
    let mut plain_labels = Vec::new();
    backend
        .segment_volume_streamed_cancellable(
            &mut plain,
            &mut plain_labels,
            &params,
            2,
            &CancelToken::never(),
        )
        .unwrap();
    let plain_reads = plain.reads();
    assert!(plain_reads > 0);

    let counted = FaultySource::new(
        Box::new(RvolReader::open(&input).unwrap()),
        FaultPlan::default(),
        0,
    );
    let mut folded = DigestSource::new(counted);
    let mut folded_labels = Vec::new();
    backend
        .segment_volume_streamed_cancellable(
            &mut folded,
            &mut folded_labels,
            &params,
            2,
            &CancelToken::never(),
        )
        .unwrap();
    let digest = folded.digest().expect("a full sweep folds the digest");
    assert_eq!(folded.mask_digest(), None, "maskless source folds no mask digest");
    let folded_reads = folded.into_inner().reads();

    assert_eq!(folded_reads, plain_reads, "the digest fold must add ZERO reads");
    assert_eq!(folded_labels, plain_labels, "the wrap must not perturb the run");
    assert_eq!(
        digest,
        raster_digest(19, 21, 8, 8, &vol.voxels),
        "streamed fold must equal the one-shot digest"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn streamed_hit_replays_identical_bytes_and_bypasses_admission() {
    let dir = tmp_dir("stream_hit");
    let cache_dir = dir.join("cache");
    let input = dir.join("in.rvol");
    volume::save_raw(&phantom_rvol(25, 27, 10), &input).unwrap();
    let params = fast_params();
    let spec = |out: &str| StreamVolumeJob {
        input: input.clone(),
        mask: None,
        output: dir.join(out),
        tile_slices: 2,
        prefetch: false,
        fault: None,
    };

    let mut cfg = Config::new();
    cfg.service.workers = 1;
    cfg.cache.dir = Some(cache_dir.display().to_string());
    let first = Service::start(&cfg).unwrap();
    let cold = first
        .submit_volume_streamed(spec("cold.rvol"), params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert!(!cold.cached);
    assert!(cold.peak_resident_bytes.unwrap() > 0);
    let snap = first.shutdown();
    assert_eq!(snap.streamed_runs, 1);
    // First contact with the file: no memoized digest existed at
    // submit, so the run was keyed by the worker's fold — no probe.
    assert_eq!(snap.cache_misses, 0);
    assert_eq!(snap.cache_hits, 0);

    // A fresh service over the same cache dir, with a resident-byte
    // budget NO streamed run could ever fit. The memoized digest keys
    // the submission, the disk store answers it, and admission control
    // is never consulted — a hit holds no tiles.
    let mut tiny = Config::new();
    tiny.service.workers = 1;
    tiny.service.resident_budget_bytes = 1;
    tiny.cache.dir = Some(cache_dir.display().to_string());
    let second = Service::start(&tiny).unwrap();
    let warm = second
        .submit_volume_streamed(spec("warm.rvol"), params, Engine::Parallel)
        .unwrap()
        .wait()
        .unwrap();
    assert!(warm.cached);
    assert_eq!(warm.peak_resident_bytes, Some(0), "a hit holds no resident tiles");
    assert_eq!(
        std::fs::read(dir.join("warm.rvol")).unwrap(),
        std::fs::read(dir.join("cold.rvol")).unwrap(),
        "replayed RVOL must be byte-identical to the cold run's"
    );
    let snap = second.shutdown();
    assert_eq!(snap.cache_hits, 1);
    assert_eq!(snap.cache_misses, 0);
    assert_eq!(snap.streamed_runs, 0, "a hit never counts as a streamed run");
    assert_eq!(snap.rejected, 0, "a hit bypasses admission entirely");
    assert_eq!(snap.submitted, 1);
    assert_eq!(snap.completed, 1);
    std::fs::remove_dir_all(&dir).unwrap();
}
