//! Whole-pipeline integration: phantom acquisition -> skull stripping ->
//! segmentation -> evaluation, plus the experiment harnesses themselves.

use repro::config::Config;
use repro::eval::dice_per_class;
use repro::fcm::{canonical_relabel, FcmParams};
use repro::image::FeatureVector;
use repro::phantom::skullstrip::{strip, StripParams};
use repro::phantom::{generate_slice, sized_dataset, PhantomConfig};
use repro::report::experiments as exp;

mod common;

#[test]
fn clinical_pipeline_with_skull_stripping() {
    // The paper's preprocessing chain (Section 5.2): raw head image ->
    // skull strip -> 4-cluster FCM -> DSC vs ground truth.
    let s = generate_slice(&PhantomConfig {
        slice: 96,
        with_skull: true,
        ..PhantomConfig::default()
    });
    let (stripped, _) = strip(&s.image, &StripParams::default());
    let fv = FeatureVector::from_image(&stripped);
    let mut run = repro::fcm::sequential::run(&fv.x, &fv.w, &FcmParams::default());
    canonical_relabel(&mut run);
    let d = dice_per_class(&run.labels, &s.ground_truth.labels, 4);
    // Stripping is imperfect at the brain rim, so thresholds are a bit
    // looser than the skull-free case (which achieves >0.9).
    assert!(d[0] > 0.97, "background DSC {d:?}");
    assert!(d[2] > 0.80, "GM DSC {d:?}");
    assert!(d[3] > 0.90, "WM DSC {d:?}");
}

#[test]
fn without_stripping_skull_corrupts_segmentation() {
    // Negative control: skipping the preprocessing step must hurt —
    // validates that the stripping substrate does real work.
    let s = generate_slice(&PhantomConfig {
        slice: 96,
        with_skull: true,
        ..PhantomConfig::default()
    });
    let strip_run = {
        let (stripped, _) = strip(&s.image, &StripParams::default());
        let fv = FeatureVector::from_image(&stripped);
        let mut r = repro::fcm::sequential::run(&fv.x, &fv.w, &FcmParams::default());
        canonical_relabel(&mut r);
        r
    };
    let raw_run = {
        let fv = FeatureVector::from_image(&s.image);
        let mut r = repro::fcm::sequential::run(&fv.x, &fv.w, &FcmParams::default());
        canonical_relabel(&mut r);
        r
    };
    let d_strip = dice_per_class(&strip_run.labels, &s.ground_truth.labels, 4);
    let d_raw = dice_per_class(&raw_run.labels, &s.ground_truth.labels, 4);
    // WM absorbs bright scalp without stripping; GM/CSF shift too.
    let mean_strip: f64 = d_strip.iter().sum::<f64>() / 4.0;
    let mean_raw: f64 = d_raw.iter().sum::<f64>() / 4.0;
    assert!(
        mean_strip > mean_raw + 0.02,
        "stripping did not help: {mean_strip:.4} vs {mean_raw:.4}"
    );
}

#[test]
fn sized_datasets_segment_at_every_table3_size_head() {
    // Head of the Table 3 sweep (full sweep lives in the benches).
    for &bytes in &[20 * 1024usize, 60 * 1024] {
        let d = sized_dataset(bytes, 5);
        let fv = FeatureVector::from_image(&d.image);
        let mut run = repro::fcm::sequential::run(&fv.x, &fv.w, &FcmParams::default());
        canonical_relabel(&mut run);
        let dsc = dice_per_class(&run.labels, &d.ground_truth.labels, 4);
        for (cls, v) in dsc.iter().enumerate() {
            assert!(*v > 0.85, "{bytes}B class {cls}: DSC {v}");
        }
    }
}

#[test]
fn fig7_harness_produces_full_table() {
    if !common::device_ready() {
        return;
    }
    let t = exp::fig7(&Config::new()).unwrap();
    let text = t.to_text();
    // 4 slices x 4 regions = 16 data rows + header + separator.
    assert_eq!(text.lines().count(), 18, "{text}");
    // Parallel and sequential DSC agree to well under 0.5% everywhere
    // (the paper's "statistically similar" claim).
    for line in text.lines().skip(2) {
        let diff: f64 = line
            .split_whitespace()
            .last()
            .unwrap()
            .parse()
            .expect("diff column");
        assert!(diff < 0.5, "DSC diff too large in: {line}");
    }
}

#[test]
fn fig5_and_fig6_write_pgms() {
    if !common::device_ready() {
        return;
    }
    let dir = std::env::temp_dir().join(format!("repro_fig_test_{}", std::process::id()));
    let cfg = Config::new();
    let wrote5 = exp::fig5(&cfg, &dir.join("fig5")).unwrap();
    assert!(wrote5.iter().filter(|l| l.ends_with(".pgm")).count() >= 9);
    let wrote6 = exp::fig6(&cfg, 96, &dir.join("fig6")).unwrap();
    assert_eq!(wrote6.len(), 5); // phantom + 4 GT masks
    for f in wrote6 {
        let img = repro::image::pgm::read(std::path::Path::new(&f)).unwrap();
        assert!(!img.is_empty());
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn table3_harness_quick_row_shape() {
    let cfg = Config::new();
    let t = exp::table3(&cfg, &[20 * 1024], 2).unwrap();
    let text = t.to_text();
    assert!(text.contains("20KB"));
    // Simulated columns must echo the paper's scale (57s / 0.1s).
    let row = text.lines().nth(2).unwrap();
    assert!(row.contains("57"), "{row}");
}

#[test]
fn reduction_demo_verifies() {
    if !common::device_ready() {
        return;
    }
    let out = exp::reduction_demo(&Config::new()).unwrap();
    assert!(out.contains("final sum"));
}

#[test]
fn speedup_model_against_all_paper_rows() {
    use repro::gpu_sim::{CostModel, PAPER_TABLE3};
    let m = CostModel::calibrated_c2050();
    // Shape assertion across the full table: ordering of speedups between
    // the three regimes (small superlinear, mid dip, large superlinear).
    let s = |kb: usize| m.speedup(kb * 1024);
    assert!(s(20) > s(200), "small-end superlinearity lost");
    assert!(s(1000) > s(200), "large-end superlinearity lost");
    assert!(s(1000) > s(20), "large end should dominate (paper: 666 > 559)");
    for &(kb, seq, par) in &PAPER_TABLE3 {
        let model = s(kb);
        let paper = seq / par;
        assert!(
            (model - paper).abs() / paper < 0.30,
            "{kb}KB: model {model:.0} vs paper {paper:.0}"
        );
    }
}

#[test]
fn robustness_harness_degrades_gracefully() {
    if !common::device_ready() {
        return;
    }
    let t = exp::robustness(&Config::new()).unwrap();
    let text = t.to_text();
    let rows: Vec<&str> = text.lines().skip(2).collect();
    assert_eq!(rows.len(), 7);
    let dsc = |row: &str| -> f64 {
        row.split_whitespace().nth(2).unwrap().parse().unwrap()
    };
    // Clean image segments best; heavy noise+INU degrades but stays sane.
    assert!(dsc(rows[0]) > 0.97, "{}", rows[0]);
    assert!(dsc(rows[0]) >= dsc(rows[3]) - 1e-9, "noise should not help");
    assert!(dsc(rows[6]) > 0.70, "worst case collapsed: {}", rows[6]);
    // Device path tracks sequential within 1% at every corruption level.
    for r in &rows {
        let seq = dsc(r);
        let par: f64 = r.split_whitespace().nth(3).unwrap().parse().unwrap();
        assert!((seq - par).abs() < 0.01, "{r}");
    }
}
