//! Integration tests over the PJRT runtime: the AOT device path against
//! the sequential baseline and the pure-jnp `ref` artifact flavor.
//!
//! These need `make artifacts` to have run AND the real xla crate linked
//! (the offline checkout vendors a stub); each test self-skips when the
//! artifacts are absent so the host-side suite stays green everywhere.

use repro::fcm::{canonical_relabel, FcmParams};
use repro::image::{pad_to, FeatureVector};
use repro::phantom::{generate_slice, PhantomConfig};
use repro::runtime::{FcmExecutor, Registry};
use std::path::Path;

mod common;

fn registry() -> Registry {
    Registry::open(Path::new("artifacts")).expect("run `make artifacts` first")
}

fn slice_features() -> (FeatureVector, Vec<u8>) {
    let s = generate_slice(&PhantomConfig::default());
    (
        FeatureVector::from_image(&s.image),
        s.ground_truth.labels.clone(),
    )
}

#[test]
fn device_matches_sequential_labels_from_same_init() {
    if !common::device_ready() {
        return;
    }
    // The paper's core functional claim (Fig. 5): the parallel FCM
    // segmentation is identical to the sequential one. Drive both paths
    // from the same padded features and the same membership init.
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let params = FcmParams::default();
    let (fv, _) = slice_features();
    let meta = reg
        .manifest
        .bucket_for(fv.len(), params.clusters, "pallas")
        .unwrap()
        .clone();
    let padded = pad_to(&fv, meta.pixels);
    let u0 = repro::fcm::init_membership_masked(params.clusters, &padded.w, params.seed);

    let (mut dev, _) = exec.segment_from(&padded, u0.clone(), &params).unwrap();
    let mut seq = repro::fcm::sequential::run_from(&padded.x, &padded.w, u0, &params);
    seq.labels.truncate(padded.n_real);

    canonical_relabel(&mut dev);
    canonical_relabel(&mut seq);
    assert_eq!(dev.iterations, seq.iterations, "iteration count differs");
    let agree = dev
        .labels
        .iter()
        .zip(&seq.labels)
        .filter(|(a, b)| a == b)
        .count();
    let frac = agree as f64 / seq.labels.len() as f64;
    assert!(frac > 0.9995, "agreement only {frac}");
    // Centers match to fp32 reduction tolerance.
    for (a, b) in dev.centers.iter().zip(&seq.centers) {
        assert!((a - b).abs() < 0.05, "{:?} vs {:?}", dev.centers, seq.centers);
    }
}

#[test]
fn pallas_flavor_matches_ref_flavor() {
    if !common::device_ready() {
        return;
    }
    // L1 kernels vs pure-jnp graph, both through the full AOT+PJRT path.
    let reg = registry();
    let params = FcmParams::default();
    let (fv, _) = slice_features();
    let meta = reg
        .manifest
        .bucket_for(fv.len(), params.clusters, "pallas")
        .unwrap()
        .clone();
    let padded = pad_to(&fv, meta.pixels);
    let u0 = repro::fcm::init_membership_masked(params.clusters, &padded.w, params.seed);

    let pallas = FcmExecutor::with_flavor(&reg, "pallas");
    let refx = FcmExecutor::with_flavor(&reg, "ref");
    let (mut a, _) = pallas.segment_from(&padded, u0.clone(), &params).unwrap();
    let (mut b, _) = refx.segment_from(&padded, u0, &params).unwrap();
    canonical_relabel(&mut a);
    canonical_relabel(&mut b);
    assert_eq!(a.iterations, b.iterations);
    let agree = a.labels.iter().zip(&b.labels).filter(|(x, y)| x == y).count();
    assert!(
        agree as f64 / a.labels.len() as f64 > 0.9995,
        "pallas vs ref agreement {agree}/{}",
        a.labels.len()
    );
}

#[test]
fn device_converges_and_recovers_tissue_centers() {
    if !common::device_ready() {
        return;
    }
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let (fv, gt) = slice_features();
    let (mut run, stats) = exec.segment(&fv, &FcmParams::default()).unwrap();
    canonical_relabel(&mut run);
    assert!(run.converged, "delta {}", run.final_delta);
    assert!(stats.iterations < 100);
    // Ascending centers near the tissue means (2, 55, 115, 165).
    let expect = [2.0f32, 55.0, 115.0, 165.0];
    for (c, e) in run.centers.iter().zip(expect) {
        assert!((c - e).abs() < 15.0, "centers {:?}", run.centers);
    }
    let d = repro::eval::dice_per_class(&run.labels, &gt, 4);
    for (cls, v) in d.iter().enumerate() {
        assert!(*v > 0.85, "class {cls} DSC {v}");
    }
}

#[test]
fn objective_decreases_on_device() {
    if !common::device_ready() {
        return;
    }
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let (fv, _) = slice_features();
    let (run, _) = exec.segment(&fv, &FcmParams::default()).unwrap();
    for w in run.jm_history.windows(2) {
        assert!(w[1] <= w[0] * (1.0 + 1e-4), "J increased: {:?}", run.jm_history);
    }
}

#[test]
fn bucket_padding_does_not_change_result() {
    if !common::device_ready() {
        return;
    }
    // Segment a 4096-px crop via its natural bucket and via a forced
    // larger bucket; converged centers must agree.
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let params = FcmParams::default();
    let s = generate_slice(&PhantomConfig::default());
    let crop = FeatureVector::from_values(
        s.image.pixels[..4096].iter().map(|&p| p as f32).collect(),
    );

    let (mut small, st_small) = exec.segment(&crop, &params).unwrap();
    assert_eq!(st_small.bucket, 4096);

    let padded = pad_to(&crop, 16384);
    let u0 = repro::fcm::init_membership_masked(params.clusters, &padded.w, params.seed);
    let (mut big, st_big) = exec.segment_from(&padded, u0, &params).unwrap();
    assert_eq!(st_big.bucket, 16384);

    canonical_relabel(&mut small);
    canonical_relabel(&mut big);
    for (a, b) in small.centers.iter().zip(&big.centers) {
        assert!((a - b).abs() < 0.5, "{:?} vs {:?}", small.centers, big.centers);
    }
    let agree = small
        .labels
        .iter()
        .zip(&big.labels[..small.labels.len()])
        .filter(|(a, b)| a == b)
        .count();
    assert!(agree as f64 / small.labels.len() as f64 > 0.995);
}

#[test]
fn brfcm_histogram_bucket_runs_on_device() {
    if !common::device_ready() {
        return;
    }
    // The n=256 artifact serves brFCM: histogram bins as weighted points.
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let s = generate_slice(&PhantomConfig::default());
    let (x, w) = repro::fcm::brfcm::reduce(&s.image.pixels);
    let fv = FeatureVector::weighted(x, w);
    let params = FcmParams {
        epsilon: 1e-4,
        ..Default::default()
    };
    let (mut run, stats) = exec.segment(&fv, &params).unwrap();
    assert_eq!(stats.bucket, 256);
    canonical_relabel(&mut run);
    // Compare with full sequential FCM on the pixels.
    let xf: Vec<f32> = s.image.pixels.iter().map(|&p| p as f32).collect();
    let wf = vec![1.0; xf.len()];
    let mut full = repro::fcm::sequential::run(&xf, &wf, &FcmParams::default());
    canonical_relabel(&mut full);
    for (a, b) in run.centers.iter().zip(&full.centers) {
        assert!((a - b).abs() < 2.5, "brfcm-device {:?} vs full {:?}", run.centers, full.centers);
    }
}

#[test]
fn block_sum_artifact_matches_host_sum() {
    if !common::device_ready() {
        return;
    }
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let a: Vec<f32> = (0..16384).map(|i| ((i * 37) % 101) as f32 * 0.25).collect();
    let partials = exec.block_sum(&a).unwrap();
    // Partial count = n / block (block policy: aot.block_for).
    assert_eq!(partials.len(), 16384 / 4096);
    let host: f32 = a.iter().sum();
    let dev: f32 = partials.iter().sum();
    assert!((host - dev).abs() / host < 1e-5, "host {host} dev {dev}");
}

#[test]
fn missing_bucket_is_a_clean_error() {
    if !common::device_ready() {
        return;
    }
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    // clusters=7 has no artifacts.
    let fv = FeatureVector::from_values(vec![1.0; 256]);
    let params = FcmParams {
        clusters: 7,
        ..Default::default()
    };
    let err = exec.segment(&fv, &params).unwrap_err();
    assert!(format!("{err:#}").contains("no fcm_iteration artifact"), "{err:#}");
}

#[test]
fn wrong_m_is_rejected() {
    if !common::device_ready() {
        return;
    }
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let fv = FeatureVector::from_values(vec![1.0; 256]);
    let padded = pad_to(&fv, 256);
    let params = FcmParams {
        m: 3.0, // artifacts are baked with m=2
        ..Default::default()
    };
    let u0 = repro::fcm::init_membership_masked(params.clusters, &padded.w, params.seed);
    let err = exec.segment_from(&padded, u0, &params).unwrap_err();
    assert!(format!("{err:#}").contains("baked with m="), "{err:#}");
}

#[test]
fn executable_cache_reuses_compilations() {
    if !common::device_ready() {
        return;
    }
    let reg = registry();
    let exec = FcmExecutor::new(&reg);
    let fv = FeatureVector::from_values(vec![10.0; 200]);
    let params = FcmParams {
        max_iters: 2,
        ..Default::default()
    };
    let _ = exec.segment(&fv, &params).unwrap();
    let n1 = reg.compiled_count();
    let _ = exec.segment(&fv, &params).unwrap();
    assert_eq!(reg.compiled_count(), n1, "second run recompiled");
}
