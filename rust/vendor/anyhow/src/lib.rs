//! Offline stand-in for the `anyhow` crate (the build environment has no
//! crates.io access). Implements exactly the subset this repository uses:
//!
//! * [`Error`] — a context-chain error (outermost context first),
//!   carrying the originating typed error for [`Error::downcast_ref`]
//!   when constructed from one ([`Error::new`] or `?` conversion),
//! * [`Result`] — `Result<T, Error>` alias with a default type parameter,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * [`anyhow!`], [`bail!`], [`ensure!`] macros,
//! * `{e}` prints the outermost message, `{e:#}` prints the full chain —
//!   matching real-anyhow formatting closely enough for the tests that
//!   assert on `format!("{err:#}")`.
//!
//! Dropping the real `anyhow` into `rust/Cargo.toml` (and deleting this
//! vendor dir) is a no-op for the rest of the codebase.

use std::fmt::{self, Display};

/// Error with a chain of context messages; `chain[0]` is the outermost
/// (most recently attached) context, `chain.last()` the root cause.
/// When built from a typed `std::error::Error` value, that value rides
/// along so callers can recover it with [`Error::downcast_ref`] — the
/// same contract as real anyhow (context layers never drop it).
pub struct Error {
    chain: Vec<String>,
    payload: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Construct from any displayable message (the `anyhow!` entry point).
    pub fn msg<M: Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
            payload: None,
        }
    }

    /// Construct from a typed error, preserving it for
    /// [`Error::downcast_ref`] — real anyhow's `Error::new`.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        // Preserve the source chain as context layers.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error {
            chain,
            payload: Some(Box::new(e)),
        }
    }

    /// Attach an outer context layer (what `.context(..)` does).
    pub fn context<C: Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The typed error this chain was built from, if it was one of type
    /// `T` (real anyhow's bound, so swapping the crates stays a no-op).
    pub fn downcast_ref<T>(&self) -> Option<&T>
    where
        T: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        self.payload.as_ref().and_then(|p| p.downcast_ref::<T>())
    }

    /// The root cause message (innermost layer).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    /// Iterate the chain from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, outermost first, `: `-joined (what
            // real anyhow prints and what the tests grep on).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts via `?`. `Error` itself deliberately does NOT
// implement `std::error::Error`, exactly like real anyhow, so this blanket
// impl cannot overlap the identity `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>` (default error type, as in the real crate).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context attachment for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display>(self, context: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {}", args)` — construct an [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// `bail!("fmt {}", args)` — early-return an `Err`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "fmt {}", args)` — `bail!` unless `cond`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: mid: root");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert!(format!("{e}").contains("file missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening config").unwrap_err();
        assert_eq!(format!("{e}"), "opening config");
        assert!(format!("{e:#}").contains("file missing"));

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros_compose() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(7).unwrap_err()), "unlucky 7");
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
    }

    #[test]
    fn downcast_ref_recovers_the_typed_error_through_context() {
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        struct Typed {
            code: u32,
        }
        impl Display for Typed {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                write!(f, "typed error {}", self.code)
            }
        }
        impl std::error::Error for Typed {}

        let e = Error::new(Typed { code: 7 }).context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed { code: 7 }));
        // `?`-style conversion preserves the payload too.
        let e: Error = Typed { code: 9 }.into();
        assert_eq!(e.downcast_ref::<Typed>().unwrap().code, 9);
        // Message-only errors carry no payload.
        assert!(Error::msg("plain").downcast_ref::<Typed>().is_none());
    }

    #[test]
    fn debug_prints_cause_chain() {
        let e = Error::msg("root").context("outer");
        let d = format!("{e:?}");
        assert!(d.contains("outer") && d.contains("Caused by") && d.contains("root"));
    }
}
