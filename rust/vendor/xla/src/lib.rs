//! Offline stub of the `xla` PJRT bindings.
//!
//! The real crate wraps libxla's PJRT C API (CPU client, HLO-text parsing,
//! compiled executables). This build environment has neither the shared
//! library nor crates.io access, so this stub provides the exact API
//! surface `repro::runtime` consumes and fails *at artifact load time*
//! with a recognizable error. Everything downstream already treats the
//! device path as optional (workers fall back to host engines; benches
//! print `-` columns), so the stub turns "cannot link" into "device rows
//! unavailable".
//!
//! The [`Literal`] type is fully functional (vec1/reshape/to_vec) because
//! tests and host-side staging use it; only HLO parsing/compilation is
//! stubbed.

use std::fmt;

/// Error type for stubbed operations. Implements `std::error::Error` so it
/// converts into `anyhow::Error` through `?`/`.context(..)`.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: xla PJRT runtime unavailable (offline stub build — link the real `xla` crate to enable the device path)"
    ))
}

/// Marker trait for element types a [`Literal`] can hold. Only f32 is used
/// by this repository; the trait keeps the generic call sites compiling.
pub trait Element: Copy + 'static {
    fn from_f32(v: f32) -> Self;
    fn into_f32(self) -> f32;
}

impl Element for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
    fn into_f32(self) -> f32 {
        self
    }
}

impl Element for f64 {
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
    fn into_f32(self) -> f32 {
        self as f32
    }
}

/// A host literal: flat f32 storage plus dimensions.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal {
            data: v.to_vec(),
            dims: vec![v.len() as i64],
        }
    }

    /// Reshape; errors if the element count changes.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal {
            data: self.data,
            dims: dims.to_vec(),
        })
    }

    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn get_first_element<T: Element>(&self) -> Result<T> {
        self.data
            .first()
            .map(|&v| T::from_f32(v))
            .ok_or_else(|| Error("get_first_element on empty literal".into()))
    }

    /// Stub literals are never tuples: executables never run.
    pub fn to_tuple1(self) -> Result<Literal> {
        Err(unavailable("to_tuple1"))
    }

    pub fn to_tuple4(self) -> Result<(Literal, Literal, Literal, Literal)> {
        Err(unavailable("to_tuple4"))
    }
}

/// Parsed HLO module (stub: never constructible from artifacts).
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("parsing HLO text"))
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// PJRT client handle. The stub client constructs (so `Registry::open`
/// gets as far as the manifest check) but cannot compile.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "cpu (xla stub — device path disabled)".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compiling HLO module"))
    }
}

/// Device buffer returned by an execution (stub: unreachable in practice).
#[derive(Debug)]
pub struct PjRtBuffer(Literal);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.0.clone())
    }
}

/// Compiled executable (stub: cannot be constructed, so `execute` is only
/// here to satisfy the call sites' types).
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[&Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("executing"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap().len(), 4);
        assert!(Literal::vec1(&[1.0]).reshape(&[3]).is_err());
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(&HloModuleProto(()));
        let err = c.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("offline stub"));
    }

    #[test]
    fn hlo_parsing_reports_stub() {
        let err = HloModuleProto::from_text_file("x.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("xla PJRT runtime unavailable"));
    }
}
