//! Quickstart: segment one phantom brain slice with the device (AOT
//! Pallas) path and compare against the sequential baseline.
//!
//!   make artifacts && cargo run --release --example quickstart

use repro::eval::dice_per_class;
use repro::fcm::{canonical_relabel, FcmParams};
use repro::image::FeatureVector;
use repro::phantom::{generate_slice, PhantomConfig};
use repro::runtime::{FcmExecutor, Registry};

fn main() -> anyhow::Result<()> {
    // 1. Data: a synthetic BrainWeb-like axial slice + exact ground truth.
    let slice = generate_slice(&PhantomConfig::default());
    let fv = FeatureVector::from_image(&slice.image);
    let params = FcmParams::default(); // c=4, m=2, eps=0.005 (the paper's)

    // 2. Parallel FCM: the AOT-lowered Pallas iteration on PJRT.
    let registry = Registry::open(std::path::Path::new("artifacts"))?;
    let executor = FcmExecutor::new(&registry);
    let (mut device_run, stats) = executor.segment(&fv, &params)?;
    canonical_relabel(&mut device_run);
    println!(
        "device : {} iterations, delta {:.4}, bucket {} ({}ms/iter)",
        device_run.iterations,
        device_run.final_delta,
        stats.bucket,
        (stats.iterate_s * 1000.0 / device_run.iterations as f64).round()
    );

    // 3. Sequential FCM: the paper's baseline.
    let mut seq_run = repro::fcm::sequential::run(&fv.x, &fv.w, &params);
    canonical_relabel(&mut seq_run);
    println!("seq    : {} iterations", seq_run.iterations);

    // 4. Evaluate both against ground truth (paper Fig. 7 metric).
    for (name, run) in [("device", &device_run), ("seq", &seq_run)] {
        let d = dice_per_class(&run.labels, &slice.ground_truth.labels, 4);
        println!(
            "{name:7}: DSC bg={:.3} csf={:.3} gm={:.3} wm={:.3}  centers={:?}",
            d[0], d[1], d[2], d[3], run.centers
        );
    }

    // 5. The paper's qualitative claim: parallel == sequential.
    let agree = device_run
        .labels
        .iter()
        .zip(&seq_run.labels)
        .filter(|(a, b)| a == b)
        .count();
    println!(
        "label agreement device vs seq: {agree}/{} ({:.2}%)",
        seq_run.labels.len(),
        100.0 * agree as f64 / seq_run.labels.len() as f64
    );
    Ok(())
}
