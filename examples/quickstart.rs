//! Quickstart: segment one phantom brain slice with every available
//! engine — the host backends (sequential / parallel / histogram) always,
//! plus the device (AOT Pallas) path when artifacts exist.
//!
//!   cargo run --release --example quickstart
//!   make artifacts && cargo run --release --example quickstart  # + device

use repro::eval::dice_per_class;
use repro::fcm::{canonical_relabel, engine, Backend, EngineOpts, FcmParams, FcmRun};
use repro::image::FeatureVector;
use repro::phantom::{generate_slice, PhantomConfig};
use repro::runtime::{FcmExecutor, Registry};

fn main() -> anyhow::Result<()> {
    // 1. Data: a synthetic BrainWeb-like axial slice + exact ground truth.
    let slice = generate_slice(&PhantomConfig::default());
    let fv = FeatureVector::from_image(&slice.image);
    let params = FcmParams::default(); // c=4, m=2, eps=0.005 (the paper's)

    // 2. Host engines: the paper's sequential baseline and the two
    //    host-parallel paths (all from the same seeded init).
    let mut runs: Vec<(String, FcmRun)> = Vec::new();
    for backend in [Backend::Sequential, Backend::Parallel, Backend::Histogram] {
        let t0 = std::time::Instant::now();
        let mut run = engine::run(&fv.x, &fv.w, &params, &EngineOpts::with_backend(backend));
        let secs = t0.elapsed().as_secs_f64();
        canonical_relabel(&mut run);
        println!("{backend:<10}: {} iterations, {secs:.3}s", run.iterations);
        runs.push((backend.to_string(), run));
    }

    // 3. Device path (optional): the AOT-lowered Pallas iteration on PJRT.
    if repro::runtime::device_available(std::path::Path::new("artifacts")) {
        let registry = Registry::open(std::path::Path::new("artifacts"))?;
        let executor = FcmExecutor::new(&registry);
        let (mut device_run, stats) = executor.segment(&fv, &params)?;
        canonical_relabel(&mut device_run);
        println!(
            "device    : {} iterations, bucket {} ({}ms/iter)",
            device_run.iterations,
            stats.bucket,
            (stats.iterate_s * 1000.0 / device_run.iterations as f64).round()
        );
        runs.push(("device".to_string(), device_run));
    } else {
        println!("device    : skipped (artifacts missing or stub xla linked)");
    }

    // 4. Evaluate all against ground truth (paper Fig. 7 metric).
    for (name, run) in &runs {
        let d = dice_per_class(&run.labels, &slice.ground_truth.labels, 4);
        println!(
            "{name:<10}: DSC bg={:.3} csf={:.3} gm={:.3} wm={:.3}  centers={:?}",
            d[0], d[1], d[2], d[3], run.centers
        );
    }

    // 5. The paper's qualitative claim: parallel == sequential — here for
    //    every engine vs the sequential baseline.
    let base = &runs[0].1;
    for (name, run) in &runs[1..] {
        let agree = run
            .labels
            .iter()
            .zip(&base.labels)
            .filter(|(a, b)| a == b)
            .count();
        println!(
            "label agreement {name} vs sequential: {agree}/{} ({:.2}%)",
            base.labels.len(),
            100.0 * agree as f64 / base.labels.len() as f64
        );
    }
    Ok(())
}
