//! Volume workflow: segment a whole phantom volume (a stack of axial
//! slices, the form the paper's BrainWeb dataset ships in) through the
//! batching service, then compute the volume-level DSC — the clinical
//! number per tissue over all voxels.
//!
//!   cargo run --release --example volume_batch          # host engine
//!   make artifacts && cargo run --release --example volume_batch  # device

use repro::config::Config;
use repro::coordinator::{Engine, Service};
use repro::fcm::FcmParams;
use repro::phantom::{generate_volume, PhantomConfig};

fn main() -> anyhow::Result<()> {
    let cfg = Config::new();
    let params = FcmParams::from(&cfg.fcm);
    // Device when the device path is usable, else the host-parallel
    // engine.
    let engine = if repro::runtime::device_available(std::path::Path::new(&cfg.artifacts_dir)) {
        Engine::Device
    } else {
        Engine::Parallel
    };
    println!("engine: {engine:?}");

    // A coarse pass over the cerebrum: every 4th slice of 80..120.
    let volume = generate_volume(&PhantomConfig::default(), 80, 120, 4);
    println!(
        "volume: {} slices, {} voxels",
        volume.slices.len(),
        volume.voxels()
    );

    let service = Service::start(&cfg)?;
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = volume
        .slices
        .iter()
        .map(|s| service.submit_image(&s.image, params, engine))
        .collect::<anyhow::Result<_>>()?;
    let predictions: Vec<Vec<u8>> = tickets
        .into_iter()
        .map(|t| t.wait().map(|r| r.labels))
        .collect::<anyhow::Result<_>>()?;
    let wall = t0.elapsed().as_secs_f64();

    let d = volume.volume_dice(&predictions, 4);
    println!(
        "segmented in {wall:.2}s ({:.1} slices/s, {:.0} kvox/s)",
        volume.slices.len() as f64 / wall,
        volume.voxels() as f64 / wall / 1000.0
    );
    println!(
        "volume DSC: background {:.4}  CSF {:.4}  GM {:.4}  WM {:.4}",
        d[0], d[1], d[2], d[3]
    );
    println!("{:#?}", service.shutdown());
    Ok(())
}
