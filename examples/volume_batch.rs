//! Volume workflow: the same phantom volume segmented two ways through
//! the service, with wall time and volume-level DSC for each —
//!
//!   1. **2-D slice loop** — every axial slice submitted as its own job
//!      (the pre-PR-3 path: the batcher groups them, but each slice is
//!      an independent 2-D FCM run);
//!   2. **true 3-D** — ONE volume job served by the slab-decomposed
//!      volumetric engine (`FcmBackend::segment_volume`), plus the 3-D
//!      histogram path whose per-iteration cost is independent of voxel
//!      count.
//!
//!   cargo run --release --example volume_batch
//!   REPRO_VOLUME_QUICK=1 cargo run --release --example volume_batch  # CI smoke
//!
//! Host-only by design (the volumetric paths are host engines), so it
//! needs no AOT artifacts; see `segment-volume --engine device` for the
//! per-slice device fallback.

use repro::config::Config;
use repro::coordinator::{Engine, Service};
use repro::eval::dice_per_class;
use repro::fcm::FcmParams;
use repro::phantom::{generate_volume, PhantomConfig};

fn main() -> anyhow::Result<()> {
    let quick = std::env::var("REPRO_VOLUME_QUICK").is_ok();
    let cfg = Config::new();
    let params = FcmParams::from(&cfg.fcm);

    // The cerebrum block of the phantom: 40 consecutive slices (quick
    // mode: 8) — the clinical object, not a slice cut out of it.
    let depth = if quick { 8 } else { 40 };
    let volume = generate_volume(&PhantomConfig::default(), 80, 80 + depth, 1);
    let vol = volume.to_voxel_volume();
    let truth = volume.ground_truth_labels();
    println!(
        "volume: {}x{}x{} = {} voxels",
        vol.width,
        vol.height,
        vol.depth,
        vol.len()
    );

    let service = Service::start(&cfg)?;
    let mean_tissue = |d: &[f64]| (d[1] + d[2] + d[3]) / 3.0;

    // --- 1. 2-D slice loop: one job per axial slice. -------------------
    let t0 = std::time::Instant::now();
    let tickets: Vec<_> = volume
        .slices
        .iter()
        .map(|s| service.submit_image(&s.image, params, Engine::Parallel))
        .collect::<anyhow::Result<_>>()?;
    let predictions: Vec<Vec<u8>> = tickets
        .into_iter()
        .map(|t| t.wait().map(|r| r.labels))
        .collect::<anyhow::Result<_>>()?;
    let wall_2d = t0.elapsed().as_secs_f64();
    let dsc_2d = volume.volume_dice(&predictions, 4);

    // --- 2. true 3-D: one volume job, slab-parallel engine. ------------
    let t0 = std::time::Instant::now();
    let r3d = service
        .submit_volume(vol.clone(), params, Engine::Parallel)?
        .wait()?;
    let wall_3d = t0.elapsed().as_secs_f64();
    let dsc_3d = dice_per_class(&r3d.labels, &truth, 4);

    // --- 3. true 3-D, histogram path (O(256·c²) per iteration). --------
    let t0 = std::time::Instant::now();
    let rh = service
        .submit_volume(vol.clone(), params, Engine::Histogram)?
        .wait()?;
    let wall_h = t0.elapsed().as_secs_f64();
    let dsc_h = dice_per_class(&rh.labels, &truth, 4);

    println!("\npath            wall(s)   kvox/s   mean tissue DSC (CSF/GM/WM)");
    for (name, wall, dsc) in [
        ("2-D slice loop", wall_2d, &dsc_2d),
        ("3-D slab-parallel", wall_3d, &dsc_3d),
        ("3-D histogram", wall_h, &dsc_h),
    ] {
        println!(
            "{name:16} {wall:8.2} {:8.0}   {:.4}  (BG {:.4} CSF {:.4} GM {:.4} WM {:.4})",
            vol.len() as f64 / wall / 1000.0,
            mean_tissue(dsc),
            dsc[0],
            dsc[1],
            dsc[2],
            dsc[3]
        );
    }
    println!(
        "\n3-D iterations: slab {} / histogram {} (converged: {} / {})",
        r3d.iterations, rh.iterations, r3d.converged, rh.converged
    );
    println!("{:#?}", service.shutdown());
    Ok(())
}
