//! Perf probe (EXPERIMENTS.md §Perf): per-iteration device time by bucket
//! and artifact flavor, isolating where the device path spends its time.
//!
//!   cargo run --release --example perf_probe [-- sizes...]

use repro::fcm::FcmParams;
use repro::image::{pad_to, FeatureVector};
use repro::phantom::sized_dataset;
use repro::report::Table;
use repro::runtime::{FcmExecutor, Registry};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    if !repro::runtime::device_available(Path::new("artifacts")) {
        println!("perf_probe needs the device path (artifacts + real xla crate); skipping");
        println!("host-engine timings: cargo bench --bench baselines");
        return Ok(());
    }
    let reg = Registry::open(Path::new("artifacts"))?;
    let params = FcmParams {
        max_iters: 8, // fixed iteration count: measure per-iter cost
        epsilon: 0.0, // never converge early
        ..Default::default()
    };

    let flavors: Vec<&str> = {
        let mut f = vec!["pallas"];
        if reg.manifest.buckets(4, "ref").len() > 1 {
            f.push("ref");
        }
        f
    };

    let mut t = Table::new(["bucket", "flavor", "compile(s)", "ms/iter", "px/us"]);
    for kb in [20usize, 100, 250, 500, 1000] {
        let data = sized_dataset(kb * 1024, 42);
        let fv = FeatureVector::from_image(&data.image);
        for flavor in &flavors {
            let exec = FcmExecutor::with_flavor(&reg, flavor);
            let meta = reg.manifest.bucket_for(fv.len(), 4, flavor)?.clone();
            let padded = pad_to(&fv, meta.pixels);
            let u0 = repro::fcm::init_membership_masked(4, &padded.w, 42);
            // Warm (includes compile).
            let c0 = reg.total_compile_seconds();
            let (_, _) = exec.segment_from(&padded, u0.clone(), &params)?;
            let compile_s = reg.total_compile_seconds() - c0;
            // Measure.
            let (_, stats) = exec.segment_from(&padded, u0, &params)?;
            let ms_per_iter = stats.iterate_s * 1000.0 / stats.iterations as f64;
            t.row([
                format!("{}", meta.pixels),
                flavor.to_string(),
                format!("{compile_s:.2}"),
                format!("{ms_per_iter:.1}"),
                format!("{:.1}", meta.pixels as f64 / (ms_per_iter * 1000.0)),
            ]);
        }
    }
    t.print();
    Ok(())
}
