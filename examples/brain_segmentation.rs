//! End-to-end driver (DESIGN.md E2/E5/E6/E7): the full clinical-style
//! pipeline of the paper on a realistic small workload —
//!
//!   phantom volume (4 slices, with skull) -> skull stripping -> parallel
//!   FCM segmentation on the fast path (AOT device when artifacts exist,
//!   else the host-parallel engine) -> DSC against ground truth, with the
//!   sequential baseline run side by side and all images written as PGMs
//!   under out/brain/.
//!
//! The numbers this prints are recorded in EXPERIMENTS.md (E5/E7).
//!
//!   cargo run --release --example brain_segmentation
//!   make artifacts && cargo run --release --example brain_segmentation

use repro::eval::{dice_per_class, Confusion};
use repro::fcm::{canonical_relabel, engine, Backend, EngineOpts, FcmParams};
use repro::image::{pgm, FeatureVector, LabelMap};
use repro::phantom::skullstrip::{strip, StripParams};
use repro::phantom::{generate_slice, PhantomConfig};
use repro::report::Table;
use repro::runtime::{FcmExecutor, Registry};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let outdir = Path::new("out/brain");
    std::fs::create_dir_all(outdir)?;
    let registry = if repro::runtime::device_available(Path::new("artifacts")) {
        Registry::open(Path::new("artifacts")).ok()
    } else {
        None
    };
    let fast_name = if registry.is_some() { "device" } else { "parallel" };
    println!("fast path: {fast_name}\n");
    let params = FcmParams::default();

    let mut table = Table::new([
        "slice", "engine", "iters", "time(s)", "DSC bg", "DSC csf", "DSC gm", "DSC wm", "acc",
    ]);
    let mut total_device_s = 0.0;
    let mut total_seq_s = 0.0;

    for slice_idx in [91usize, 96, 101, 111] {
        // 1. Acquire: phantom slice WITH skull + scalp (the raw input the
        //    paper's preprocessing had to clean).
        let s = generate_slice(&PhantomConfig {
            slice: slice_idx,
            with_skull: true,
            noise_sigma: 4.0,
            ..PhantomConfig::default()
        });
        pgm::write(&s.image, &outdir.join(format!("s{slice_idx}_raw.pgm")))?;

        // 2. Preprocess: morphological skull stripping (paper Sec. 5.2).
        let (stripped, _mask) = strip(&s.image, &StripParams::default());
        pgm::write(&stripped, &outdir.join(format!("s{slice_idx}_stripped.pgm")))?;

        let fv = FeatureVector::from_image(&stripped);

        // 3a. Parallel FCM: device path when artifacts exist, host-
        //     parallel engine otherwise.
        let t0 = std::time::Instant::now();
        let mut dev = match &registry {
            Some(reg) => FcmExecutor::new(reg).segment(&fv, &params)?.0,
            None => {
                let opts = EngineOpts::with_backend(Backend::Parallel);
                engine::run(&fv.x, &fv.w, &params, &opts)
            }
        };
        let dev_s = t0.elapsed().as_secs_f64();
        total_device_s += dev_s;
        canonical_relabel(&mut dev);

        // 3b. Sequential baseline.
        let t1 = std::time::Instant::now();
        let mut seq = repro::fcm::sequential::run(&fv.x, &fv.w, &params);
        let seq_s = t1.elapsed().as_secs_f64();
        total_seq_s += seq_s;
        canonical_relabel(&mut seq);

        // 4. Evaluate + write label maps.
        for (engine, run, secs) in [(fast_name, &dev, dev_s), ("seq", &seq, seq_s)] {
            let d = dice_per_class(&run.labels, &s.ground_truth.labels, 4);
            let acc = Confusion::new(&run.labels, &s.ground_truth.labels, 4).accuracy();
            table.row([
                format!("{slice_idx}"),
                engine.to_string(),
                format!("{}", run.iterations),
                format!("{secs:.3}"),
                format!("{:.4}", d[0]),
                format!("{:.4}", d[1]),
                format!("{:.4}", d[2]),
                format!("{:.4}", d[3]),
                format!("{acc:.4}"),
            ]);
            let lm = LabelMap::from_labels(stripped.width, stripped.height, run.labels.clone());
            pgm::write(
                &lm.to_image(4),
                &outdir.join(format!("s{slice_idx}_{engine}.pgm")),
            )?;
        }

        let agree = dev
            .labels
            .iter()
            .zip(&seq.labels)
            .filter(|(a, b)| a == b)
            .count();
        println!(
            "slice {slice_idx}: {fast_name}/seq agreement {:.2}% ({agree}/{})",
            100.0 * agree as f64 / seq.labels.len() as f64,
            seq.labels.len()
        );
    }

    println!();
    table.print();
    println!(
        "\ntotals: {fast_name} {total_device_s:.2}s, sequential {total_seq_s:.2}s; images in {}",
        outdir.display()
    );
    Ok(())
}
