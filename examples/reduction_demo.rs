//! Algorithm-2 demo (paper Fig. 3 / experiment E3): the shared-memory tree
//! reduction, re-expressed as a Pallas grid reduction, executed on-device
//! via the AOT artifact, and cross-checked against a host sum.
//!
//!   make artifacts && cargo run --release --example reduction_demo

use repro::config::Config;
use repro::report::experiments as exp;

fn main() -> anyhow::Result<()> {
    // The paper's Fig. 3 walks a 16-element example with 4 CUDA blocks:
    // show the same structure at our block granularity, on the device
    // (host fallback: the engine's fixed-order tree, same shape).
    match exp::reduction_demo(&Config::new()) {
        Ok(out) => print!("{out}"),
        Err(e) => {
            println!("device reduction skipped ({e})");
            // Host analogue: the deterministic chunked tree the parallel
            // engine uses for its sigma sums.
            let n = 16384usize;
            let a: Vec<f64> = (0..n).map(|i| (i % 7) as f64).collect();
            let chunks = repro::fcm::engine::reduce::chunk_ranges(n, 2048);
            let partials: Vec<f64> = chunks
                .iter()
                .map(|&(s, l)| a[s..s + l].iter().sum())
                .collect();
            let total = repro::fcm::engine::reduce::tree_sum(&partials);
            println!(
                "host Algorithm-2 analogue: {n} elements -> {} partials -> sum {total} (flat {})",
                partials.len(),
                a.iter().sum::<f64>()
            );
        }
    }

    // The paper's headline reduction arithmetic: a 1 MB input with
    // blockDim=128 shrinks to 4 KB of partials ("1048576/128 << 1").
    let n: usize = 1 << 20;
    let block = 2048;
    println!(
        "our analogue at block={block}: {n} elements -> {} partials ({} KB)",
        n / block,
        n / block * 4 / 1024
    );
    Ok(())
}
