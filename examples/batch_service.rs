//! Batching service demo: mixed-size, mixed-engine segmentation workload
//! through the L3 coordinator — shape-bucket batching, true batched
//! execution (a formed batch runs as ONE `segment_batch` engine
//! invocation; parallel batches interleave all images through one pool
//! pass per iteration), backpressure, per-job latency percentiles, and
//! per-engine batching-efficiency metrics. Device jobs are included only
//! when AOT artifacts exist; the host engines (parallel/histogram)
//! always run.
//!
//!   cargo run --release --example batch_service
//!   make artifacts && cargo run --release --example batch_service  # + device

use repro::config::Config;
use repro::coordinator::{Engine, Service};
use repro::fcm::FcmParams;
use repro::image::FeatureVector;
use repro::phantom::{generate_slice, sized_dataset, PhantomConfig};
use repro::util::Summary;

fn main() -> anyhow::Result<()> {
    let mut cfg = Config::new();
    cfg.service.workers = 2;
    cfg.service.max_batch = 4;
    cfg.service.batch_execute = true; // the default; spelled out for the demo
    let params = FcmParams::from(&cfg.fcm);

    let service = Service::start(&cfg)?;

    // A mixed workload: full slices and small crops on the host-parallel
    // engine, histogram fast-path jobs, and (when artifacts exist) device
    // jobs. Same-shape same-engine jobs co-batch (all full slices share
    // one shape key, all crops another); nothing co-batches across
    // engines — watch the batch ids in the output.
    let device = repro::runtime::device_available(std::path::Path::new("artifacts"));
    let mut tickets = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..6u64 {
        let s = generate_slice(&PhantomConfig {
            slice: 80 + (i as usize * 7) % 40,
            seed: i,
            ..PhantomConfig::default()
        });
        if device {
            tickets.push((
                "slice/device",
                service.submit_image(&s.image, params, Engine::Device)?,
            ));
        }
        tickets.push((
            "slice/parallel",
            service.submit_image(&s.image, params, Engine::Parallel)?,
        ));

        let crop = sized_dataset(12 * 1024, i);
        tickets.push((
            "crop/parallel",
            service.submit_image(&crop.image, params, Engine::Parallel)?,
        ));

        tickets.push((
            "slice/histogram",
            service.submit(FeatureVector::from_image(&s.image), params, Engine::Histogram)?,
        ));
    }

    let mut latencies = Vec::new();
    let mut by_kind: std::collections::BTreeMap<&str, Vec<f64>> = Default::default();
    for (kind, t) in tickets {
        let r = t.wait()?;
        let total = r.queue_wait_s + r.service_s;
        latencies.push(total);
        by_kind.entry(kind).or_default().push(total);
        println!(
            "{kind:13} job {:2} worker {} batch {:2}: wait {:6.3}s service {:6.3}s iters {:3} centers {:?}",
            r.id, r.worker, r.batch_id, r.queue_wait_s, r.service_s, r.iterations,
            r.centers.iter().map(|c| c.round()).collect::<Vec<_>>()
        );
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\nper-kind latency (s):");
    for (kind, lats) in &by_kind {
        let s = Summary::of(lats);
        println!(
            "  {kind:13} mean {:.3}  p95 {:.3}  max {:.3}",
            s.mean, s.p95, s.max
        );
    }
    let s = Summary::of(&latencies);
    println!(
        "\noverall: {} jobs in {wall:.2}s ({:.2} jobs/s), latency mean {:.3}s p95 {:.3}s",
        latencies.len(),
        latencies.len() as f64 / wall,
        s.mean,
        s.p95
    );

    let snap = service.shutdown();
    println!("\nbatching efficiency (one engine invocation per batch):");
    for e in &snap.per_engine {
        println!(
            "  {:10} batches {:2}  jobs {:2}  mean batch size {:.2}  mean batch latency {:.3}s",
            e.engine, e.batches, e.jobs, e.mean_batch_size, e.mean_batch_latency_s
        );
    }
    println!("\n{snap:#?}");
    Ok(())
}
