//! Speedup study (Table 3 + Fig. 8, quick form): regenerates the paper's
//! performance evaluation through the calibrated C2050/i5 cost model and
//! measures this stack's own host-engine (and, with artifacts, device)
//! ratios alongside.
//!
//!   cargo run --release --example speedup_study
//!   make artifacts && cargo run --release --example speedup_study  # + device

use repro::config::Config;
use repro::report::experiments as exp;

fn main() -> anyhow::Result<()> {
    let cfg = Config::new();

    println!("== Table 3 (quick sizes; `repro bench-table3` for all 14) ==\n");
    let sizes = exp::table3_sizes(true);
    exp::table3(&cfg, &sizes, 3)?.print();

    println!("\n== Fig. 8 speedup curve (calibrated model) ==\n");
    let (table, chart) = exp::fig8(&exp::fig8_sizes());
    table.print();
    println!("\n{chart}");

    println!("== Ablation (Sec. 5.3 open questions) ==\n");
    exp::ablation(&[100 * 1024, 200 * 1024, 500 * 1024]).print();
    Ok(())
}
